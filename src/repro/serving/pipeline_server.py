"""Online pipeline serving: concurrent requests over one optimized plan.

``PipelineServer`` is the layer between a finished optimization run and
live traffic: it takes the winning :class:`~repro.pipeline.Pipeline`
(``SearchResult.best().pipeline``) plus a ``Backend`` and serves
*independent single-document requests* against it under concurrency.

Design:

- **Admission queue.** ``submit`` grants one of ``max_inflight`` slots
  (queued + executing requests). A saturated server applies
  backpressure: blocking submit waits for a slot, ``block=False`` (or a
  timeout) raises :class:`ServerSaturated` — the caller sheds load
  instead of growing an unbounded queue.
- **Micro-batching window.** The serving loop opens a
  ``batch_window_s`` window when the first request of a batch arrives,
  coalescing up to ``max_batch`` waiting requests. The batch is then
  driven through ``Executor.run_session`` — the same merged-dispatch
  machinery that batches sibling *search candidates* — so concurrent
  requests' LLM calls at the same pipeline stage share
  ``Backend.submit`` chunks: an 8-request batch over a 3-LLM-op plan
  pays ~3 round trips, not 24. Results are bit-identical to per-request
  execution (``run_session``'s contract), so coalescing is purely a
  throughput/latency decision.
- **SLO accounting.** Every request is timestamped at submit /
  admission / batch start / completion; :class:`ServerStats` reports
  p50/p95/p99 latency split into queue wait vs execute time, token and
  cost totals, batch-size distribution, and SLO attainment against an
  optional ``slo_s`` target.
- **Graceful drain.** ``shutdown(drain=True)`` stops admission,
  finishes every queued and in-flight request, then joins the loop
  thread; ``drain=False`` cancels queued requests (their tickets carry
  :class:`ServerClosed`) while the executing batch still completes.
- **Control plane.** Admission, window sizing, and shedding route
  through a :class:`~repro.serving.control.ControlPolicy`
  (``StaticPolicy`` by default — bit-identical to the inlined
  decisions it replaced; ``AdaptivePolicy`` senses recent SLO
  attainment and sheds per tenant). Plans hot-swap without draining:
  :meth:`swap_plan` routes new admissions to the new plan while
  tickets already admitted finish on the one they were admitted under
  (each ticket binds its plan at admission).

Determinism: throughput numbers on a wall clock are not reproducible,
so the server also runs **virtual-time traces**: ``run_trace`` replays a
seeded open-loop arrival schedule against a :class:`VirtualClock` that
only a latency-modeled backend (:class:`VirtualLatencyBackend`)
advances. Same arrivals + same seed -> bit-identical outputs *and*
bit-identical latency/throughput stats, which is what
``benchmarks/serve_bench.py`` and the CI bench-regression gate assert.
"""

from __future__ import annotations

import math
import threading
import time
from collections import deque
from collections.abc import Mapping
from dataclasses import dataclass, field, fields as _dc_fields
from typing import (Any, Callable, Deque, Dict, Iterator, List, Optional,
                    Sequence, Tuple)

from repro.analysis.analyzer import AnalysisReport, analyze as _analyze
from repro.data.documents import Dataset, Document
from repro.engine.executor import (CallCache, ExecutionStats, Executor,
                                   SessionResult)
from repro.engine.operators import pipeline_hash, validate_pipeline
from repro.pipeline.model import PipelineLike, as_config
from repro.pipeline.protocols import backend_close, batch_hint
from repro.serving.control import (GLOBAL_INFLIGHT, TENANT_QUEUE,
                                   ControlPolicy, StaticPolicy,
                                   resolve_plan)


_UNSET_SLO = object()  # "use the server's slo_s" sentinel


def validate_slo(slo_s: Optional[float], what: str) -> Optional[float]:
    """SLO targets are seconds, positive, and finite — everywhere.
    ``None`` (no target) passes through. Raises ``ValueError`` naming
    ``what`` otherwise; shared by both server constructors and
    ``TenantSpec``."""
    if slo_s is None:
        return None
    slo = float(slo_s)
    if not (slo > 0 and math.isfinite(slo)):
        raise ValueError(f"{what}: slo_s must be a positive finite "
                         f"number of seconds, got {slo_s!r}")
    return slo


@dataclass(frozen=True)
class SwapRecord(Mapping):
    """Typed record of one hot plan swap — what :meth:`swap_plan`
    returns on both servers. ``before`` is the swapped stats'
    ``recent_summary()`` taken under the admission lock at swap time;
    ``report()`` lists the same record (as a plain dict) under
    ``swaps`` with an ``after`` summary measured at report time.

    Implements the ``Mapping`` protocol, so pre-existing dict-style
    access (``record["new_hash"]``, ``dict(record)``) keeps working.
    """

    tenant: Optional[str]
    at: float
    old_plan: str
    new_plan: str
    old_hash: str
    new_hash: str
    before: Dict[str, Any]

    def __getitem__(self, key: str) -> Any:
        if key in self.__dataclass_fields__:
            return getattr(self, key)
        raise KeyError(key)

    def __iter__(self) -> Iterator[str]:
        return iter(self.__dataclass_fields__)

    def __len__(self) -> int:
        return len(_dc_fields(self))

    def as_dict(self) -> Dict[str, Any]:
        return {name: getattr(self, name)
                for name in self.__dataclass_fields__}


class ServerClosed(RuntimeError):
    """The server no longer accepts (or cancelled) this request."""


class ServerSaturated(RuntimeError):
    """Admission refused under load. ``reason`` says which policy bound
    fired: ``"global_inflight"`` (all ``max_inflight`` slots taken —
    backpressure) or ``"tenant_queue"`` (a per-tenant queue bound shed
    the request or evicted it from the queue). ``tenant`` names the
    affected tenant on multi-tenant hosts."""

    def __init__(self, message: str = "server saturated", *,
                 reason: str = GLOBAL_INFLIGHT,
                 tenant: Optional[str] = None):
        super().__init__(message)
        self.reason = reason
        self.tenant = tenant


# -- clocks -----------------------------------------------------------------


class MonotonicClock:
    """Wall-clock time source for live serving (``time.monotonic``)."""

    virtual = False

    def now(self) -> float:
        return time.monotonic()


class VirtualClock:
    """Deterministic logical clock for reproducible serving traces.

    Nothing advances it implicitly: a latency-modeled backend charges
    round-trip time via :meth:`advance`, and the trace driver jumps to
    arrival times via :meth:`advance_to`. Two runs with the same
    arrival schedule and backend therefore read identical timestamps.
    """

    virtual = True

    def __init__(self, start: float = 0.0):
        self._t = float(start)
        self._lock = threading.Lock()

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        with self._lock:
            self._t += max(0.0, float(dt))
            return self._t

    def advance_to(self, t: float) -> float:
        with self._lock:
            self._t = max(self._t, float(t))
            return self._t


class VirtualLatencyBackend:
    """Latency model over any deterministic backend.

    Each ``submit`` advances a :class:`VirtualClock` by
    ``base_s + per_request_s * len(batch)`` — the shape of a remote
    batched LLM endpoint, where the per-call round trip dominates and
    marginal requests are cheap — then delegates to the wrapped
    backend, so *results* are bit-identical to the unwrapped substrate
    while *time* is fully modeled. Round trips serialize on the clock
    (``concurrent_submit = False``), keeping virtual timelines
    single-valued.
    """

    concurrent_submit = False

    def __init__(self, inner: Any, clock: VirtualClock, *,
                 base_s: float = 0.05, per_request_s: float = 0.0,
                 preferred_batch_size: Optional[int] = None):
        self.inner = inner
        self.clock = clock
        self.base_s = base_s
        self.per_request_s = per_request_s
        self.preferred_batch_size = (
            preferred_batch_size if preferred_batch_size is not None
            else batch_hint(inner))

    def __getattr__(self, name: str) -> Any:
        # deterministic / fingerprint / usage_cost / run_* pass through
        return getattr(self.inner, name)

    def __repr__(self) -> str:
        return (f"VirtualLatencyBackend({self.inner!r}, "
                f"base={self.base_s}, per_req={self.per_request_s})")

    def submit(self, requests):
        self.clock.advance(self.base_s + self.per_request_s * len(requests))
        return self.inner.submit(requests)


# -- per-request accounting -------------------------------------------------


@dataclass
class ServeTicket:
    """Handle for one submitted document: resolves to the pipeline's
    output documents for it (``docs``), its :class:`ExecutionStats`, or
    a per-request ``error`` — plus the timestamps SLO accounting uses.
    """

    rid: int
    doc: Document
    submitted_at: float
    tenant: Optional[str] = None
    priority: int = 0
    # the pipeline config this request was admitted under — hot swaps
    # change what *future* admissions bind, never a live ticket's plan
    plan: Any = field(default=None, repr=False)
    admitted_at: float = 0.0
    started_at: float = 0.0
    finished_at: float = 0.0
    docs: Optional[Dataset] = None
    stats: Optional[ExecutionStats] = None
    error: Optional[Exception] = None
    _event: threading.Event = field(default_factory=threading.Event,
                                    repr=False)

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._event.wait(timeout)

    def result(self, timeout: Optional[float] = None) -> Dataset:
        """Block until served; return the output documents or raise the
        request's error."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} not finished")
        if self.error is not None:
            raise self.error
        return self.docs

    @property
    def latency_s(self) -> float:
        return self.finished_at - self.submitted_at

    @property
    def queue_wait_s(self) -> float:
        return self.started_at - self.submitted_at

    @property
    def execute_s(self) -> float:
        return self.finished_at - self.started_at


@dataclass(frozen=True)
class RequestRecord:
    """Immutable accounting row of one finished request."""

    rid: int
    submitted_at: float
    started_at: float
    finished_at: float
    ok: bool
    batch_size: int
    llm_calls: int = 0
    in_tokens: int = 0
    out_tokens: int = 0
    cost: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.finished_at - self.submitted_at

    @property
    def queue_wait_s(self) -> float:
        return self.started_at - self.submitted_at

    @property
    def execute_s(self) -> float:
        return self.finished_at - self.started_at


def _percentile(sorted_vals: List[float], q: float) -> float:
    """Nearest-rank percentile over pre-sorted values (deterministic —
    no interpolation, so virtual-clock traces reproduce exactly)."""
    if not sorted_vals:
        return 0.0
    n = len(sorted_vals)
    rank = max(1, math.ceil(q / 100.0 * n))  # 1-indexed nearest rank
    return sorted_vals[min(rank, n) - 1]


def _dist(vals: List[float]) -> Dict[str, float]:
    s = sorted(vals)
    return {
        "p50": _percentile(s, 50), "p95": _percentile(s, 95),
        "p99": _percentile(s, 99),
        "mean": sum(s) / len(s) if s else 0.0,
        "max": s[-1] if s else 0.0,
    }


class P2Quantile:
    """P²-style online quantile estimator (Jain & Chlamtac 1985):
    tracks one quantile of an unbounded stream in O(1) memory — five
    markers whose heights approximate the quantile curve, adjusted
    piecewise-parabolically as observations stream in. Exact for the
    first five observations; after that the estimate tracks the true
    quantile without retaining any samples."""

    __slots__ = ("q", "_heights", "_pos", "_want", "_inc")

    def __init__(self, q: float):
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {q}")
        self.q = q
        self._heights: List[float] = []
        self._pos = [1.0, 2.0, 3.0, 4.0, 5.0]
        self._want = [1.0, 1 + 2 * q, 1 + 4 * q, 3 + 2 * q, 5.0]
        self._inc = [0.0, q / 2, q, (1 + q) / 2, 1.0]

    def observe(self, x: float) -> None:
        h = self._heights
        if len(h) < 5:
            h.append(float(x))
            h.sort()
            return
        if x < h[0]:
            h[0] = float(x)
            k = 0
        elif x >= h[4]:
            h[4] = float(x)
            k = 3
        else:
            k = next(i for i in range(4) if h[i] <= x < h[i + 1])
        for i in range(k + 1, 5):
            self._pos[i] += 1
        for i in range(5):
            self._want[i] += self._inc[i]
        n = self._pos
        for i in (1, 2, 3):
            d = self._want[i] - n[i]
            if (d >= 1 and n[i + 1] - n[i] > 1) or \
                    (d <= -1 and n[i - 1] - n[i] < -1):
                s = 1.0 if d > 0 else -1.0
                cand = h[i] + s / (n[i + 1] - n[i - 1]) * (
                    (n[i] - n[i - 1] + s) * (h[i + 1] - h[i])
                    / (n[i + 1] - n[i])
                    + (n[i + 1] - n[i] - s) * (h[i] - h[i - 1])
                    / (n[i] - n[i - 1]))
                if not (h[i - 1] < cand < h[i + 1]):
                    # parabolic prediction left the bracket: linear step
                    j = i + int(s)
                    cand = h[i] + s * (h[j] - h[i]) / (n[j] - n[i])
                h[i] = cand
                n[i] += s

    def value(self) -> float:
        h = self._heights
        if not h:
            return 0.0
        if len(h) < 5:
            return _percentile(h, self.q * 100.0)  # kept sorted
        return h[2]


class MetricSketch:
    """Bounded accounting of one duration metric: running
    count/sum/max plus one :class:`P2Quantile` per reported percentile
    — O(1) memory however many requests stream through."""

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.max = 0.0
        self._p50 = P2Quantile(0.50)
        self._p95 = P2Quantile(0.95)
        self._p99 = P2Quantile(0.99)

    def observe(self, x: float) -> None:
        self.count += 1
        self.total += x
        if x > self.max:
            self.max = x
        self._p50.observe(x)
        self._p95.observe(x)
        self._p99.observe(x)

    def dist(self) -> Dict[str, float]:
        return {
            "p50": self._p50.value(), "p95": self._p95.value(),
            "p99": self._p99.value(),
            "mean": self.total / self.count if self.count else 0.0,
            "max": self.max,
        }


class ServerStats:
    """Aggregated serving accounting, reported as one dict.

    Two retention modes share the reporting surface:

    - ``mode="exact"`` keeps one :class:`RequestRecord` per finished
      request; :meth:`report` derives every number from the full record
      set, so virtual-time traces (``run_trace``) stay bit-reproducible.
      Memory grows with request count — only acceptable for bounded
      traces.
    - ``mode="sketch"`` is the live-server mode: O(1) memory per metric.
      Counters (requests, tokens, cost, batches) accumulate as scalars,
      each duration metric keeps a :class:`MetricSketch` (P² online
      percentiles — approximate, typically within a few percent of the
      exact nearest-rank value), SLO violations are counted online
      against the ``slo_s`` fixed at construction, and a rolling window
      of the last ``window`` records feeds a ``recent`` section with
      exact percentiles over that window. A long-lived threaded server
      no longer grows without bound.

    All counters are guarded — the serving loop and caller threads
    observe concurrently.
    """

    def __init__(self, opened_at: float = 0.0, mode: str = "exact",
                 slo_s: Optional[float] = None, window: int = 512):
        if mode not in ("exact", "sketch"):
            raise ValueError(f"unknown stats mode {mode!r} "
                             f"(expected 'exact' or 'sketch')")
        self.opened_at = opened_at
        self.mode = mode
        self.slo_s = slo_s
        self.window = max(1, window)
        self.rejected = 0
        self.cancelled = 0
        self.shed: Dict[str, int] = {}  # rejections by policy reason
        self._lock = threading.Lock()
        if mode == "exact":
            self.records: List[RequestRecord] = []
            self.batch_sizes: List[int] = []
        else:
            self._requests = 0
            self._completed = 0
            self._failed = 0
            self._llm_calls = 0
            self._in_tokens = 0
            self._out_tokens = 0
            self._cost = 0.0
            self._slo_violations = 0
            self._batches = 0
            self._batch_sum = 0
            self._batch_max = 0
            self._last_finished = opened_at
            self._metrics = {"latency_s": MetricSketch(),
                             "queue_wait_s": MetricSketch(),
                             "execute_s": MetricSketch()}
            self._recent: Deque[RequestRecord] = deque(maxlen=self.window)

    def observe(self, record: RequestRecord) -> None:
        with self._lock:
            if self.mode == "exact":
                self.records.append(record)
                return
            self._requests += 1
            if record.ok:
                self._completed += 1
            else:
                self._failed += 1
            self._llm_calls += record.llm_calls
            self._in_tokens += record.in_tokens
            self._out_tokens += record.out_tokens
            self._cost += record.cost
            if record.finished_at > self._last_finished:
                self._last_finished = record.finished_at
            self._recent.append(record)
            if record.ok:
                self._metrics["latency_s"].observe(record.latency_s)
                self._metrics["queue_wait_s"].observe(record.queue_wait_s)
                self._metrics["execute_s"].observe(record.execute_s)
                if self.slo_s is not None and \
                        record.latency_s > self.slo_s:
                    self._slo_violations += 1

    def observe_batch(self, size: int) -> None:
        with self._lock:
            if self.mode == "exact":
                self.batch_sizes.append(size)
                return
            self._batches += 1
            self._batch_sum += size
            if size > self._batch_max:
                self._batch_max = size

    def count_rejected(self, reason: Optional[str] = None) -> None:
        with self._lock:
            self.rejected += 1
            if reason is not None:
                self.shed[reason] = self.shed.get(reason, 0) + 1

    def count_cancelled(self, n: int = 1) -> None:
        with self._lock:
            self.cancelled += n

    def recent_summary(self) -> Dict[str, Any]:
        """The control plane's sensor: latency/SLO summary over the
        rolling window of recent finished requests (sketch mode's
        ``_recent`` deque; the last ``window`` records in exact mode).
        ``attainment`` is None when no SLO target is configured; an
        empty window reports ``n=0`` with optimistic attainment 1.0 —
        policies treat no-signal as healthy."""
        with self._lock:
            if self.mode == "sketch":
                recent = list(self._recent)
            else:
                recent = self.records[-self.window:]
        ok = [r for r in recent if r.ok]
        lat = sorted(r.latency_s for r in ok)
        summary: Dict[str, Any] = {
            "n": len(ok),
            "mean_latency_s": sum(lat) / len(lat) if lat else 0.0,
            "p95_latency_s": _percentile(lat, 95),
            "slo_s": self.slo_s,
        }
        if self.slo_s is None:
            summary["violations"] = None
            summary["attainment"] = None
        else:
            violations = sum(1 for v in lat if v > self.slo_s)
            summary["violations"] = violations
            summary["attainment"] = (1.0 - violations / len(lat)
                                     if lat else 1.0)
        return summary

    def report(self, *, elapsed_s: Optional[float] = None,
               slo_s: Optional[float] = None,
               extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        if self.mode == "sketch":
            # sketch mode counts SLO violations online against the
            # construction-time target — it cannot re-score retired
            # requests against a different one. Refuse loudly rather
            # than silently reporting against the stale target.
            if slo_s is not None and slo_s != self.slo_s:
                raise ValueError(
                    f"sketch-mode stats score SLO online against the "
                    f"construction-time slo_s={self.slo_s}; cannot "
                    f"re-report against slo_s={slo_s}")
            return self._report_sketch(elapsed_s=elapsed_s, extra=extra)
        with self._lock:
            records = list(self.records)
            batches = list(self.batch_sizes)
            rejected, cancelled = self.rejected, self.cancelled
            shed = dict(self.shed)
        completed = [r for r in records if r.ok]
        failed = [r for r in records if not r.ok]
        if elapsed_s is None:
            end = max((r.finished_at for r in records),
                      default=self.opened_at)
            elapsed_s = end - self.opened_at
        lat = [r.latency_s for r in completed]
        rep: Dict[str, Any] = {
            "stats_mode": "exact",
            "requests": len(records),
            "completed": len(completed),
            "failed": len(failed),
            "rejected": rejected,
            "rejected_reasons": shed,
            "cancelled": cancelled,
            "batches": len(batches),
            "mean_batch_size": (sum(batches) / len(batches)
                                if batches else 0.0),
            "max_batch_size": max(batches, default=0),
            "elapsed_s": elapsed_s,
            "throughput_rps": (len(completed) / elapsed_s
                               if elapsed_s > 0 else 0.0),
            "latency_s": _dist(lat),
            "queue_wait_s": _dist([r.queue_wait_s for r in completed]),
            "execute_s": _dist([r.execute_s for r in completed]),
            "llm_calls": sum(r.llm_calls for r in records),
            "in_tokens": sum(r.in_tokens for r in records),
            "out_tokens": sum(r.out_tokens for r in records),
            "cost": sum(r.cost for r in records),
        }
        if slo_s is not None:
            violations = sum(1 for v in lat if v > slo_s)
            rep["slo"] = {
                "slo_s": slo_s,
                "violations": violations,
                "attainment": (1.0 - violations / len(lat)) if lat else 1.0,
            }
        if extra:
            rep.update(extra)
        return rep

    def _report_sketch(self, *, elapsed_s: Optional[float],
                       extra: Optional[Dict[str, Any]]) -> Dict[str, Any]:
        with self._lock:
            requests, completed = self._requests, self._completed
            failed = self._failed
            rejected, cancelled = self.rejected, self.cancelled
            shed = dict(self.shed)
            batches = self._batches
            batch_sum, batch_max = self._batch_sum, self._batch_max
            if elapsed_s is None:
                elapsed_s = self._last_finished - self.opened_at
            dists = {k: m.dist() for k, m in self._metrics.items()}
            recent = list(self._recent)
            violations = self._slo_violations
            llm_calls = self._llm_calls
            in_tokens, out_tokens = self._in_tokens, self._out_tokens
            cost = self._cost
        recent_ok = [r for r in recent if r.ok]
        rep: Dict[str, Any] = {
            "stats_mode": "sketch",
            "requests": requests,
            "completed": completed,
            "failed": failed,
            "rejected": rejected,
            "rejected_reasons": shed,
            "cancelled": cancelled,
            "batches": batches,
            "mean_batch_size": batch_sum / batches if batches else 0.0,
            "max_batch_size": batch_max,
            "elapsed_s": elapsed_s,
            "throughput_rps": (completed / elapsed_s
                               if elapsed_s > 0 else 0.0),
            "latency_s": dists["latency_s"],
            "queue_wait_s": dists["queue_wait_s"],
            "execute_s": dists["execute_s"],
            "llm_calls": llm_calls,
            "in_tokens": in_tokens,
            "out_tokens": out_tokens,
            "cost": cost,
            "recent": {
                "window": len(recent),
                "latency_s": _dist([r.latency_s for r in recent_ok]),
                "queue_wait_s": _dist([r.queue_wait_s for r in recent_ok]),
                "execute_s": _dist([r.execute_s for r in recent_ok]),
            },
        }
        if self.slo_s is not None:
            rep["slo"] = {
                "slo_s": self.slo_s,
                "violations": violations,
                "attainment": (1.0 - violations / completed
                               if completed else 1.0),
            }
        if extra:
            rep.update(extra)
        return rep


# -- the server -------------------------------------------------------------


class PipelineServer:
    """Serve one optimized pipeline to concurrent single-document
    requests (see module docstring for the design).

    Two drive modes share the same batch-execution path:

    - **threaded** (live traffic): :meth:`start` spawns the serving
      loop; :meth:`submit` returns a :class:`ServeTicket`;
      :meth:`shutdown` drains. Timestamps come from ``clock``
      (``MonotonicClock`` by default).
    - **virtual-time trace** (benchmarks/tests): :meth:`run_trace`
      replays an ``(arrival_time, doc)`` schedule deterministically
      against a :class:`VirtualClock` shared with a latency-modeled
      backend — no threads, reproducible stats.

    ``workers`` is forwarded to ``Executor.run_session``: it caps how
    many merged-stage chunks ride the backend concurrently, exactly as
    in parallel search. ``max_batch=1`` degenerates to one-request-at-
    a-time execution — the baseline the serving benchmark beats.
    """

    def __init__(self, pipeline: PipelineLike, backend: Any, *,
                 max_inflight: int = 32, max_batch: int = 8,
                 batch_window_s: float = 0.005, workers: int = 4,
                 seed: int = 0, fail_prob: float = 0.0,
                 slo_s: Optional[float] = None, clock: Any = None,
                 executor: Optional[Executor] = None,
                 call_cache: Optional[CallCache] = None,
                 cache_entries: int = 65536,
                 stats_mode: str = "auto", stats_window: int = 512,
                 policy: Optional[ControlPolicy] = None):
        self._config = as_config(pipeline)
        validate_pipeline(self._config)
        # static field-flow analysis: refuse plans with error diagnostics
        # (undefined reads, aliasing names, unknown models, ...) before
        # they serve a single request — the gate the hot-swap path needs
        _analyze(self._config).raise_for_errors()
        if max_batch > max_inflight:
            raise ValueError(f"max_batch={max_batch} exceeds "
                             f"max_inflight={max_inflight}")
        if stats_mode not in ("auto", "exact", "sketch"):
            raise ValueError(f"unknown stats_mode {stats_mode!r}")
        self.clock = clock if clock is not None else MonotonicClock()
        # serving episodes are long-lived and see unbounded distinct
        # documents: the default call cache is LRU-bounded so duplicate
        # traffic still hits (the exact-hit tier in front of dispatch)
        # while memory stays capped. Callers inject their own cache —
        # e.g. a repro.cache.PersistentCallCache shared across hosts —
        # via call_cache=, or a whole executor via executor=.
        if executor is None and call_cache is None:
            call_cache = CallCache(max_entries=max(1, cache_entries))
        self.executor = executor if executor is not None else Executor(
            backend, seed=seed, fail_prob=fail_prob, call_cache=call_cache)
        self.max_inflight = max(1, max_inflight)
        self.max_batch = max(1, max_batch)
        self.batch_window_s = max(0.0, batch_window_s)
        self.workers = max(1, workers)
        self.slo_s = validate_slo(slo_s, type(self).__name__)
        # "auto": exact records for virtual-time traces (bit-reproducible
        # reports), bounded sketch for the long-lived threaded loop
        self.stats_mode = stats_mode
        self.stats_window = stats_window
        self._cond = threading.Condition()
        self._queue: Deque[ServeTicket] = deque()
        self._inflight = 0
        self._closed = False
        self._drain_on_close = True
        self._thread: Optional[threading.Thread] = None
        self._rid = 0
        self._dispatch_base: Dict[str, int] = {}
        self._swaps: List[SwapRecord] = []
        # finished-request observers (fn(ticket, record)) — the feed a
        # ReoptLoop's per-tenant reservoir samples from; the attached
        # loop (if any) contributes report()'s "reopt" section
        self._request_observers: List[Callable[[ServeTicket,
                                                RequestRecord], None]] = []
        self._reopt: Any = None
        # the control plane: admission / window / shedding decisions
        # route through the policy; the default reproduces the
        # pre-control-plane behavior bit-identically
        self.policy = policy if policy is not None else StaticPolicy()
        self.policy.bind(self)
        self._reset_episode(trace=True)

    # -- episode lifecycle ----------------------------------------------------

    def _resolved_stats_mode(self, *, trace: bool) -> str:
        if self.stats_mode != "auto":
            return self.stats_mode
        return "exact" if trace else "sketch"

    def _new_stats(self, opened_at: float, *, trace: bool,
                   slo_s: Optional[float] = _UNSET_SLO) -> ServerStats:
        return ServerStats(
            opened_at=opened_at,
            mode=self._resolved_stats_mode(trace=trace),
            slo_s=self.slo_s if slo_s is _UNSET_SLO else slo_s,
            window=self.stats_window)

    def _reset_episode(self, *, trace: bool) -> None:
        """Open a fresh serving episode: stats, request ids, and the
        dispatch-counter baseline restart so reports cover exactly this
        episode (``report()`` subtracts the baseline, so a shared or
        reused executor doesn't leak foreign submit counts in)."""
        self.stats = self._new_stats(self.clock.now(), trace=trace)
        self._rid = 0
        self._dispatch_base = dict(self.executor.dispatch_stats)
        self._cache_base = self.executor.call_cache.counters()
        self._swaps = []
        self.policy.reset()

    # -- queue discipline (overridden by multi-tenant hosts) ------------------

    def _enqueue(self, tk: ServeTicket) -> None:
        self._queue.append(tk)

    def _queued(self) -> int:
        return len(self._queue)

    def _queued_for(self, tenant: Optional[str]) -> int:
        """Admitted, not-yet-executing requests charged to ``tenant``
        (the single-plan server has one implicit tenant)."""
        return len(self._queue)

    def _queue_snapshot(self, tenant: Optional[str]
                        ) -> List[ServeTicket]:
        """The queued tickets a policy may pick an eviction victim
        from. Only queued (never executing) tickets are evictable."""
        return list(self._queue)

    def _remove_queued(self, tk: ServeTicket) -> None:
        self._queue.remove(tk)

    def _oldest_admitted(self) -> float:
        """Admission time of the longest-waiting queued ticket (the one
        whose arrival opens the micro-batch window)."""
        return self._queue[0].admitted_at

    def _take_batch(self) -> List[ServeTicket]:
        take = min(self.max_batch, len(self._queue))
        return [self._queue.popleft() for _ in range(take)]

    def _drain_queues(self) -> List[ServeTicket]:
        out = list(self._queue)
        self._queue.clear()
        return out

    # -- shared batch execution ---------------------------------------------

    def _make_ticket(self, doc: Document, submitted_at: float,
                     tenant: Optional[str] = None,
                     priority: int = 0) -> ServeTicket:
        self._rid += 1
        return ServeTicket(rid=self._rid, doc=doc,
                           submitted_at=submitted_at, tenant=tenant,
                           priority=priority,
                           plan=self._plan_for(tenant))

    def _arrival_ticket(self, rest: Tuple, submitted_at: float
                        ) -> ServeTicket:
        """Build the ticket for one trace-arrival entry; ``rest`` is the
        entry minus its arrival time — ``(doc,)`` or
        ``(doc, priority)`` here, ``(tenant, doc[, priority])`` for
        multi-tenant hosts."""
        doc = rest[0]
        priority = int(rest[1]) if len(rest) > 1 else 0
        return self._make_ticket(doc, submitted_at=submitted_at,
                                 priority=priority)

    def _arrival_meta(self, rest: Tuple) -> Tuple[Optional[str], int]:
        """``(tenant, priority)`` of one trace-arrival entry, read
        without building its ticket (admission decisions peek before
        committing a request id)."""
        return None, (int(rest[1]) if len(rest) > 1 else 0)

    def analyze(self, *, source_fields: Optional[Sequence[str]] = None
                ) -> AnalysisReport:
        """Static field-flow analysis of the served plan. Pass the
        request documents' field names as ``source_fields`` for full
        undefined-read checking (the constructor's gate runs open-world
        since request schemas aren't known yet)."""
        return _analyze(self._config, source_fields=source_fields)

    def _job_config(self, tk: ServeTicket) -> Any:
        """The pipeline the batch job for this ticket evaluates: the
        plan bound at admission, so a hot swap never retargets a ticket
        already in the house."""
        return tk.plan if tk.plan is not None else self._plan_for(tk.tenant)

    # -- plan routing + hot swap ----------------------------------------------

    def _plan_for(self, tenant: Optional[str]) -> Any:
        """The config new admissions for ``tenant`` bind right now."""
        return self._config

    def _set_plan(self, tenant: Optional[str], config: Any) -> None:
        self._config = config

    def _swap_stats(self, tenant: Optional[str]) -> ServerStats:
        """The stats whose ``recent`` window frames a swap's
        before/after deltas."""
        return self.stats

    def _has_slo_target(self) -> bool:
        """Whether any SLO target exists for a feedback policy to
        sense against."""
        return self.slo_s is not None

    def swap_plan(self, plan: Any, *,
                  tenant: Optional[str] = None) -> SwapRecord:
        """Drain-free hot swap to ``plan`` (a ``Pipeline``, config
        dict, or ``SearchResult`` — the optimizer output promotes
        directly). The new plan is validated and gated by the static
        analyzer first; the swap is then atomic under the admission
        lock: tickets admitted before it (queued *or* executing) finish
        on the plan they bound at admission, every later admission
        binds the new plan. The executor — and with it the (persistent)
        call cache — stays attached, so calls the old plan already paid
        for warm-start the new one. Returns the :class:`SwapRecord`
        (old/new plan hashes + the before-swap ``recent`` sensor
        summary), which ``report()`` also lists under ``swaps`` with
        the after-swap summary — measured deltas for a human to judge,
        not an auto-promotion.

        One signature across both servers: the single-plan host serves
        one implicit tenant, so ``tenant`` must stay ``None`` here;
        ``MultiPipelineServer`` requires it.
        """
        if tenant is not None:
            raise ValueError(
                f"single-plan server hosts no named tenants (got "
                f"tenant={tenant!r}); tenant= addresses a "
                f"MultiPipelineServer plan")
        return self._swap(None, plan)

    def _swap(self, tenant: Optional[str], plan: Any) -> SwapRecord:
        config = resolve_plan(plan)
        validate_pipeline(config)
        # same gate as construction: statically-broken plans never
        # reach admission, swaps included
        _analyze(config).raise_for_errors()
        with self._cond:
            old = self._plan_for(tenant)
            record = SwapRecord(
                tenant=tenant,
                # episode-relative, like the report's elapsed_s
                at=self.clock.now() - self.stats.opened_at,
                old_plan=old.get("name", ""),
                new_plan=config.get("name", ""),
                old_hash=pipeline_hash(old),
                new_hash=pipeline_hash(config),
                before=self._swap_stats(tenant).recent_summary(),
            )
            self._set_plan(tenant, config)
            self._swaps.append(record)
        return record

    def _job_tags(self, batch: List[ServeTicket]
                  ) -> Optional[List[Optional[str]]]:
        """Session tags attributing dispatch volume (multi-tenant)."""
        return None

    def _observe_batch(self, batch: List[ServeTicket]) -> None:
        self.stats.observe_batch(len(batch))

    def _observe_record(self, tk: ServeTicket,
                        record: RequestRecord) -> None:
        self.stats.observe(record)

    def add_request_observer(
            self, observe: Callable[[ServeTicket, RequestRecord], None]
    ) -> None:
        """Register ``observe(ticket, record)`` to run on the serving
        path after every finished request (both drive modes, both
        servers). Observers run on the batch-execution path: they must
        be fast and must not call back into the serving API. This is
        the feed a :class:`~repro.serving.reopt.ReoptLoop` samples
        served documents from."""
        with self._cond:
            self._request_observers.append(observe)

    def _count_rejected(self, tenant: Optional[str],
                        reason: Optional[str] = None) -> None:
        self.stats.count_rejected(reason)

    def _count_cancelled(self, cancelled: List[ServeTicket]) -> None:
        self.stats.count_cancelled(len(cancelled))

    def _execute_batch(self, batch: List[ServeTicket]) -> None:
        """Run one coalesced batch through a cross-pipeline dispatch
        session: every request is an independent single-document job, so
        sibling requests' stage batches merge into shared
        ``Backend.submit`` chunks while outputs stay bit-identical to
        per-request execution — also across *heterogeneous* pipelines
        (multi-tenant hosts feed one plan per ticket)."""
        start = self.clock.now()
        for tk in batch:
            tk.started_at = start
        jobs: List[Tuple[Any, Dataset]] = [(self._job_config(tk), [tk.doc])
                                           for tk in batch]
        workers = self.workers if len(batch) > 1 else 1
        try:
            results = self.executor.run_session(jobs, workers=workers,
                                                capture_errors=True,
                                                tags=self._job_tags(batch))
        except Exception as e:  # noqa: BLE001 — resolved per ticket
            # run_session(capture_errors=True) converts backend and
            # coordinator failures into per-job errors; this net is the
            # last resort so that *no* exception can leave tickets
            # unresolved (result() hanging forever) or kill the serving
            # loop thread
            results = [SessionResult(docs=None, stats=ExecutionStats(),
                                     error=e) for _ in batch]
        end = self.clock.now()
        self._observe_batch(batch)
        for tk, res in zip(batch, results):
            tk.docs = res.docs
            tk.stats = res.stats
            tk.error = res.error
            tk.finished_at = end
            st = res.stats or ExecutionStats()
            record = RequestRecord(
                rid=tk.rid, submitted_at=tk.submitted_at,
                started_at=tk.started_at, finished_at=tk.finished_at,
                ok=res.error is None, batch_size=len(batch),
                llm_calls=st.llm_calls, in_tokens=st.in_tokens,
                out_tokens=st.out_tokens, cost=st.cost)
            self._observe_record(tk, record)
            for observe in self._request_observers:
                observe(tk, record)
            tk._event.set()

    # -- threaded mode -------------------------------------------------------

    def start(self) -> "PipelineServer":
        # the threaded loop waits out the micro-batch window and submit
        # deadlines on time.monotonic(); a VirtualClock would silently
        # mix virtual timestamps with wall-clock waits — fail fast and
        # point at the trace mode instead (mirrors run_trace's guard)
        if getattr(self.clock, "virtual", False):
            raise TypeError("threaded serving requires a real-time clock "
                            "(MonotonicClock); use run_trace for "
                            "VirtualClock serving")
        with self._cond:
            if self._closed:
                raise ServerClosed("server already shut down")
            if self._thread is not None:
                return self
            # the throughput clock starts when serving starts, not when
            # the server object was built; threaded episodes default to
            # the bounded sketch stats (a live server is unbounded in
            # request count, so its accounting must be O(1) per metric)
            self._reset_episode(trace=False)
            self._thread = threading.Thread(target=self._loop,
                                            name="repro-pipeline-server",
                                            daemon=True)
            self._thread.start()
        return self

    def __enter__(self) -> "PipelineServer":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.shutdown(drain=exc_type is None)

    def submit(self, doc: Document, *, priority: int = 0,
               block: bool = True,
               timeout: Optional[float] = None) -> ServeTicket:
        """Admit one document. The control policy decides: blocking
        submits wait out backpressure (bounded by ``timeout``),
        ``block=False`` raises :class:`ServerSaturated` immediately,
        and a shedding policy raises it even for blocking callers.
        ``priority`` only matters to policies that shed: a
        higher-priority request may evict a queued lower-priority one
        instead of being shed itself."""
        return self._submit_doc(doc, None, priority=priority,
                                block=block, timeout=timeout)

    def _shed_ticket(self, tk: ServeTicket, reason: str,
                     now: float) -> None:
        """Resolve a shed request: the ticket carries
        :class:`ServerSaturated` and the shed is counted per reason."""
        tk.started_at = now
        tk.finished_at = now
        tk.error = ServerSaturated(f"shed by {self.policy.name} "
                                   f"policy ({reason})",
                                   reason=reason, tenant=tk.tenant)
        self._count_rejected(tk.tenant, reason)
        tk._event.set()

    def _evict_locked(self, victim: ServeTicket) -> None:
        """Under ``_cond``: shed one queued (never executing) ticket so
        a higher-priority admission can take its slot."""
        self._remove_queued(victim)
        self._inflight -= 1
        self._shed_ticket(victim, TENANT_QUEUE, self.clock.now())

    def _submit_doc(self, doc: Document, tenant: Optional[str], *,
                    block: bool, timeout: Optional[float],
                    priority: int = 0) -> ServeTicket:
        if self._thread is None:
            raise RuntimeError("server not started (call start() or use "
                               "run_trace for virtual-time serving)")
        submitted = self.clock.now()
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while True:
                if self._closed:
                    raise ServerClosed("server is shutting down")
                decision = self.policy.admit(tenant=tenant,
                                             priority=priority,
                                             inflight=self._inflight)
                if decision.admit:
                    if decision.evict is not None:
                        self._evict_locked(decision.evict)
                    break
                if decision.shed:
                    self._count_rejected(tenant, decision.reason)
                    raise ServerSaturated(
                        f"request shed ({decision.reason})",
                        reason=decision.reason, tenant=tenant)
                if not block:
                    self._count_rejected(tenant, decision.reason)
                    raise ServerSaturated(
                        f"{self.max_inflight} requests in flight",
                        reason=decision.reason, tenant=tenant)
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    self._count_rejected(tenant, decision.reason)
                    raise ServerSaturated(
                        f"no admission slot within {timeout}s",
                        reason=decision.reason, tenant=tenant)
                self._cond.wait(remaining)
            tk = self._make_ticket(doc, submitted, tenant=tenant,
                                   priority=priority)
            tk.admitted_at = self.clock.now()
            self._inflight += 1
            self._enqueue(tk)
            self._cond.notify_all()
        return tk

    def serve(self, docs: Sequence[Document],
              timeout: Optional[float] = None) -> List[ServeTicket]:
        """Convenience: submit every document (blocking admission) and
        wait for all tickets."""
        tickets = [self.submit(d) for d in docs]
        for tk in tickets:
            tk.wait(timeout)
        return tickets

    def _cancel_queued_locked(self) -> bool:
        """Under ``_cond``: if a non-drain shutdown was requested,
        resolve every queued ticket with :class:`ServerClosed` and
        report True (the loop must exit)."""
        if not (self._closed and not self._drain_on_close):
            return False
        cancelled = self._drain_queues()
        self._inflight -= len(cancelled)
        self._count_cancelled(cancelled)
        self._cond.notify_all()
        now = self.clock.now()
        for tk in cancelled:
            # stamp the cancellation time so the latency properties
            # measure time-to-resolution instead of going negative
            tk.started_at = now
            tk.finished_at = now
            tk.error = ServerClosed("cancelled at shutdown")
            tk._event.set()
        return True

    def _loop(self) -> None:
        while True:
            with self._cond:
                while not self._queued() and not self._closed:
                    self._cond.wait()
                if not self._queued():
                    break  # closed and nothing left to serve
                if self._cancel_queued_locked():
                    break
                # micro-batch window: the first waiting request opens it;
                # more requests coalesce until the window closes or the
                # batch fills (shutdown closes it early). The policy
                # sizes the window per batch — StaticPolicy returns the
                # fixed batch_window_s
                window_s = self.policy.window_s()
                if window_s > 0 and \
                        self._queued() < self.max_batch:
                    close_at = time.monotonic() + window_s
                    while self._queued() < self.max_batch and \
                            not self._closed:
                        left = close_at - time.monotonic()
                        if left <= 0:
                            break
                        self._cond.wait(left)
                # a non-drain shutdown that arrived during the window
                # cancels the batch we were about to form
                if self._cancel_queued_locked():
                    break
                batch = self._take_batch()
            try:
                self._execute_batch(batch)
            finally:
                with self._cond:
                    self._inflight -= len(batch)
                    self._cond.notify_all()

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until no request is queued or executing."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while self._inflight > 0:
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    return False
                self._cond.wait(remaining)
        return True

    def shutdown(self, *, drain: bool = True,
                 timeout: Optional[float] = None,
                 close_backend: bool = False) -> bool:
        """Stop admission and stop the serving loop. ``drain=True``
        serves every queued request first; ``drain=False`` cancels the
        queue (tickets resolve with :class:`ServerClosed`) — the batch
        already executing always completes either way.

        Returns whether the serving loop actually stopped within
        ``timeout``. A False return means a batch is still executing:
        the backend is then NOT closed (``close_backend`` only applies
        to a stopped loop — closing under an in-flight batch would pull
        live state out from under it); call again to finish.
        """
        with self._cond:
            self._closed = True
            self._drain_on_close = drain
            self._cond.notify_all()
            thread = self._thread
        stopped = True
        if thread is not None:
            thread.join(timeout)
            stopped = not thread.is_alive()
        if close_backend and stopped:
            backend_close(self.executor.backend)
        return stopped

    # -- virtual-time trace mode ---------------------------------------------

    def run_trace(self, arrivals: Sequence[Tuple[float, Document]], *,
                  events: Optional[Sequence[Tuple[float, Any]]] = None
                  ) -> List[ServeTicket]:
        """Replay an open-loop arrival schedule in virtual time.

        ``arrivals`` is a list of ``(arrival_time, doc)`` — with an
        optional trailing ``priority`` int per entry; arrival times
        are relative to the trace's start (the shared clock's position
        at the call), so schedules can always start at 0. The simulation
        reproduces the threaded server's semantics — policy-driven
        admission, micro-batch window, serial batch execution — but all
        waiting is a clock jump and all execution time is whatever the
        latency-modeled backend charges, so the resulting tickets and
        :class:`ServerStats` are bit-for-bit reproducible. Requires a
        :class:`VirtualClock` (shared with the backend); refuses to run
        next to a live serving loop.

        ``events`` is an optional schedule of ``(time, fn)`` control
        actions — ``fn(server)`` runs when the virtual clock reaches
        ``time`` (before arrivals at the same instant), which is how a
        trace swaps a plan mid-flight deterministically::

            server.run_trace(arrivals,
                             events=[(0.5, lambda s: s.swap_plan(p2))])

        Requests a shedding policy refuses still appear in the returned
        ticket list, resolved with :class:`ServerSaturated`.

        Traces on one server share the executor's ``CallCache``: with a
        deterministic backend, requests already answered in an earlier
        trace are served from cache without touching ``Backend.submit``
        — i.e. without being charged model latency. That measures a
        warm-cache server, which is what re-tracing one server means;
        for fresh-cache numbers build a fresh server per trace (as
        ``benchmarks/serve_bench.py`` does).
        """
        if self._thread is not None:
            raise RuntimeError("run_trace needs exclusive use of the "
                               "server (threaded loop is running)")
        if not getattr(self.clock, "virtual", False):
            raise TypeError("run_trace requires a VirtualClock (pass "
                            "clock=VirtualClock() and share it with a "
                            "VirtualLatencyBackend)")
        # each trace is a fresh serving episode: stats, request ids, the
        # dispatch-counter baseline, and the time origin restart so
        # back-to-back traces report independently instead of
        # accumulating the prior trace's records, submits, or elapsed
        # clock into this trace's numbers (call-cache state deliberately
        # carries over — see above)
        clock = self.clock
        self._reset_episode(trace=True)
        t0 = clock.now()
        # one time-ordered queue of (t, kind, seq, payload): kind 0 =
        # control event, kind 1 = arrival; events outrank arrivals at
        # the same instant ("subsequent admissions" of a swap include
        # same-time arrivals), seq keeps the sort stable
        entries = [(t0 + float(a[0]), 1, i, tuple(a[1:]))
                   for i, a in enumerate(arrivals)]
        entries += [(t0 + float(t), 0, i, fn)
                    for i, (t, fn) in enumerate(events or [])]
        pending: Deque[Tuple] = deque(
            sorted(entries, key=lambda e: (e[0], e[1], e[2])))
        waiting: Deque[ServeTicket] = deque()  # blocked submitters
        tickets: List[ServeTicket] = []        # admitted go to _enqueue
        inflight = 0

        def admit(tk: ServeTicket, at: float) -> None:
            nonlocal inflight
            tk.admitted_at = at
            inflight += 1
            self._enqueue(tk)

        def evict(victim: ServeTicket) -> None:
            nonlocal inflight
            self._remove_queued(victim)
            inflight -= 1
            self._shed_ticket(victim, TENANT_QUEUE, clock.now())

        def offer(tk: ServeTicket, at: float) -> None:
            """One admission attempt — the trace's blocking submit:
            admit (possibly evicting), shed now, or park as a blocked
            submitter in ``waiting``."""
            decision = self.policy.admit(tenant=tk.tenant,
                                         priority=tk.priority,
                                         inflight=inflight)
            if decision.admit:
                if decision.evict is not None:
                    evict(decision.evict)
                admit(tk, at=at)
            elif decision.shed:
                self._shed_ticket(tk, decision.reason, clock.now())
            else:
                waiting.append(tk)

        def intake(until: float) -> None:
            """Entries due by ``until``: control events fire, arrivals
            enter the admission flow at their arrival time."""
            while pending and pending[0][0] <= until:
                t, kind, _seq, payload = pending.popleft()
                if kind == 0:
                    payload(self)
                    continue
                tk = self._arrival_ticket(payload, submitted_at=t)
                tickets.append(tk)
                offer(tk, at=t)

        def drain_waiting() -> None:
            while waiting:
                tk = waiting[0]
                decision = self.policy.admit(tenant=tk.tenant,
                                             priority=tk.priority,
                                             inflight=inflight)
                if decision.admit:
                    waiting.popleft()
                    if decision.evict is not None:
                        evict(decision.evict)
                    admit(tk, at=clock.now())
                elif decision.shed:
                    # the tenant saturated while this submitter waited
                    self._shed_ticket(waiting.popleft(),
                                      decision.reason, clock.now())
                else:
                    break

        while pending or waiting or self._queued():
            if not self._queued() and not waiting:
                # idle: jump to the next arrival or control event
                clock.advance_to(pending[0][0])
            intake(clock.now())
            drain_waiting()
            if not self._queued():
                continue
            # the batch window opens when the (serial) serving loop
            # picks the queue up — for a backlogged queue that is the
            # previous batch's finish time, not the requests'
            # mid-execution admission times — and in-window arrivals
            # join until the batch fills
            window_open = max(self._oldest_admitted(), clock.now())
            window_close = window_open + self.policy.window_s()
            while (self._queued() < self.max_batch
                   and pending and pending[0][0] <= window_close):
                t, kind, _seq, payload = pending[0]
                if kind == 0:
                    pending.popleft()
                    clock.advance_to(t)
                    payload(self)
                    continue
                tenant, priority = self._arrival_meta(payload)
                decision = self.policy.admit(tenant=tenant,
                                             priority=priority,
                                             inflight=inflight)
                if not decision.admit and not decision.shed:
                    break  # would block: a later intake parks it
                pending.popleft()
                clock.advance_to(t)
                tk = self._arrival_ticket(payload, submitted_at=t)
                tickets.append(tk)
                if decision.shed:
                    self._shed_ticket(tk, decision.reason, clock.now())
                    continue
                if decision.evict is not None:
                    evict(decision.evict)
                admit(tk, at=t)
            if self._queued() < self.max_batch:
                # a live server cannot know no further request is coming:
                # it always waits the window out
                clock.advance_to(window_close)
            batch = self._take_batch()
            self._execute_batch(batch)  # the backend advances the clock
            # arrivals during execution found the admission queue open;
            # the batch's slots free only at its finish time
            intake(clock.now())
            inflight -= len(batch)
            drain_waiting()
        return tickets

    # -- reporting -----------------------------------------------------------

    def report(self, *, elapsed_s: Optional[float] = None) -> Dict[str, Any]:
        """The :class:`ServerStats` report plus the merged-dispatch
        counters (submit calls, merged stages/requests) of *this serving
        episode* — deltas since start()/run_trace, so the coalescing
        evidence sits next to the latency evidence it belongs to even on
        a reused executor. ``control`` snapshots the policy's state;
        ``swaps`` lists this episode's hot swaps, each with the plan
        hashes and the ``recent`` sensor summary measured before the
        swap and again at report time."""
        dispatch = {k: v - self._dispatch_base.get(k, 0)
                    for k, v in self.executor.dispatch_stats.items()}
        control = {"policy": self.policy.name}
        control.update(self.policy.snapshot())
        swaps = [dict(rec,
                      after=self._swap_stats(rec["tenant"]
                                             ).recent_summary())
                 for rec in self._swaps]
        # cache counters are episode deltas like the dispatch counters;
        # entry counts are absolute (the cache outlives episodes)
        cc = self.executor.call_cache
        cache = {k: v - self._cache_base.get(k, 0)
                 for k, v in cc.counters().items() if k != "entries"}
        cache["entries"] = len(cc)
        persistent = getattr(cc, "persistent_stats", None)
        if callable(persistent):
            cache["store_entries"] = persistent()["store_entries"]
            cache["mode"] = cc.mode
        extra = {"dispatch": dispatch, "call_cache": cache,
                 "control": control, "swaps": swaps}
        if self._reopt is not None:
            # the attached re-optimization loop's run history — absent
            # on plain servers, so loop-free reports stay bit-identical
            extra["reopt"] = self._reopt.snapshot()
        return self.stats.report(
            elapsed_s=elapsed_s, slo_s=self.slo_s, extra=extra)
