"""Serving control plane: pluggable admission / window / shedding policy.

PRs 4-5 inlined every serving-policy decision — when to admit, how long
to hold the micro-batch window open, when to shed — in
``PipelineServer`` / ``MultiPipelineServer``. This module extracts them
into an explicit :class:`ControlPolicy` object the servers consult, so
the *mechanism* (queues, batching, ticket resolution) and the *policy*
(what the mechanism should do under the observed load) evolve
separately:

- :class:`StaticPolicy` reproduces the pre-control-plane servers
  bit-identically: admission is the global ``max_inflight`` bound, the
  window is the fixed ``batch_window_s``, nothing is ever shed. It is
  the default on both servers.
- :class:`AdaptivePolicy` is a feedback controller. Its sensor is the
  stats layer's ``recent`` window (:meth:`ServerStats.recent_summary`
  — the last ``stats_window`` finished requests, the same rolling
  window the sketch mode reports): observed SLO attainment drives
  (a) an AIMD-adjusted micro-batch window (halve under SLO pressure,
  recover additively toward the configured ``batch_window_s``) and
  (b) per-tenant admission-queue bounds that tighten for tenants whose
  recent attainment is below target, shedding that tenant's overflow
  with priority eviction instead of backpressuring the whole host.

Every admission attempt resolves to an :class:`AdmissionDecision` with
three outcomes:

========  ==================================================
admit     take a slot now; ``evict`` optionally names a
          queued lower-priority victim shed to make room
wait      no capacity yet — blocking submitters wait for a
          slot, non-blocking ones get ``ServerSaturated``
shed      reject *now*, even for blocking callers: per-tenant
          load shedding must not convert a flood into an
          unbounded crowd of blocked submitters
========  ==================================================

``reason`` carries which bound fired (``"global_inflight"`` vs
``"tenant_queue"``) into :class:`ServerSaturated` and the per-reason
shed counters in :class:`ServerStats`.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import TYPE_CHECKING, Any, Dict, Mapping, Optional, Union

from repro.pipeline.model import as_config

if TYPE_CHECKING:  # circular at runtime: pipeline_server imports us
    from repro.serving.pipeline_server import PipelineServer, ServeTicket

#: admission refused by the global ``max_inflight`` bound
GLOBAL_INFLIGHT = "global_inflight"
#: admission refused (or a queued victim evicted) by a per-tenant
#: queue bound
TENANT_QUEUE = "tenant_queue"


@dataclass(frozen=True)
class AdmissionDecision:
    """Outcome of one admission attempt (see module docstring)."""

    admit: bool
    shed: bool = False
    reason: Optional[str] = None
    evict: Optional["ServeTicket"] = None

    @staticmethod
    def wait(reason: str) -> "AdmissionDecision":
        return AdmissionDecision(admit=False, shed=False, reason=reason)

    @staticmethod
    def shed_now(reason: str) -> "AdmissionDecision":
        return AdmissionDecision(admit=False, shed=True, reason=reason)

    @staticmethod
    def admit_evicting(victim: "ServeTicket") -> "AdmissionDecision":
        return AdmissionDecision(admit=True, reason=TENANT_QUEUE,
                                 evict=victim)


ADMIT = AdmissionDecision(admit=True)


def resolve_plan(plan: Any) -> Dict[str, Any]:
    """Normalize anything the swap/serve surface accepts into a pipeline
    config dict: a ``Pipeline``, a config mapping, or a ``SearchResult``
    (anything with a callable ``best()`` whose winner has ``.pipeline``
    — both optimizer result types satisfy this)."""
    best = getattr(plan, "best", None)
    if callable(best) and not isinstance(plan, Mapping):
        plan = best().pipeline
    return as_config(plan)


class ControlPolicy:
    """Admission / window / shedding decisions for one server.

    A policy instance is bound to exactly one server (:meth:`bind`, done
    by the server constructor) and consulted under the server's
    admission lock — implementations must not block or call back into
    the public serving API. The server exposes the sensor surface a
    policy may read: ``max_inflight``, ``batch_window_s``,
    ``_queued_for(tenant)`` / ``_queue_snapshot(tenant)`` (admitted,
    not-yet-executing tickets), and ``stats`` / ``tenant_stats`` with
    :meth:`ServerStats.recent_summary`.
    """

    name = "abstract"

    def __init__(self) -> None:
        self.server: Optional["PipelineServer"] = None

    def bind(self, server: "PipelineServer") -> None:
        if self.server is not None and self.server is not server:
            raise RuntimeError(
                f"{type(self).__name__} is already bound to another "
                f"server; policies hold per-server state — build one "
                f"instance per host")
        self.server = server

    def reset(self) -> None:
        """A fresh serving episode opened (start()/run_trace)."""

    def window_s(self) -> float:
        """Micro-batch window for the batch about to form. Called once
        per batch formation; adaptive policies update their control
        state here."""
        raise NotImplementedError

    def admit(self, *, tenant: Optional[str], priority: int,
              inflight: int) -> AdmissionDecision:
        """Decide one admission attempt. ``inflight`` is the current
        queued+executing slot count (passed in because trace mode tracks
        it outside the threaded server's counter)."""
        raise NotImplementedError

    def snapshot(self) -> Dict[str, Any]:
        """Control state for ``report()``'s ``control`` section. Must
        not mutate policy state (reports are read-only)."""
        return {}


class StaticPolicy(ControlPolicy):
    """The pre-control-plane behavior, bit-identical: global
    ``max_inflight`` backpressure, fixed ``batch_window_s``, no
    per-tenant bounds, no shedding, no eviction."""

    name = "static"

    def window_s(self) -> float:
        return self.server.batch_window_s

    def admit(self, *, tenant: Optional[str], priority: int,
              inflight: int) -> AdmissionDecision:
        if inflight < self.server.max_inflight:
            return ADMIT
        return AdmissionDecision.wait(GLOBAL_INFLIGHT)

    def snapshot(self) -> Dict[str, Any]:
        return {"window_s": self.server.batch_window_s}


class AdaptivePolicy(ControlPolicy):
    """Feedback control from observed SLO attainment (see module
    docstring).

    Parameters
    ----------
    slo_target:
        Attainment the controller defends (fraction of recent completed
        requests inside their SLO). Below it, the window shrinks and
        the under-attaining tenant's queue bound tightens.
    max_queue:
        Per-tenant admitted-queue bound — an int for all tenants or a
        ``{tenant: bound}`` mapping (missing tenants use
        ``default_queue``). The single-plan server has one implicit
        tenant (``None``), so the bound applies to its global queue.
    min_queue:
        Floor the tightened bound never goes below (a tenant always
        keeps some service — shedding is load control, not a ban).
    window_floor_s / shrink / grow:
        AIMD knobs for the micro-batch window: under SLO pressure the
        window multiplies by ``shrink`` (toward ``window_floor_s``),
        otherwise it recovers by ``grow * batch_window_s`` per batch up
        to the configured ``batch_window_s``.

    The host (or its tenants) must carry an SLO target — without one
    the sensor has nothing to measure, so :meth:`bind` refuses.
    """

    name = "adaptive"

    def __init__(self, *, slo_target: float = 0.9,
                 max_queue: Union[int, Mapping[str, int]] = 16,
                 default_queue: int = 16, min_queue: int = 2,
                 window_floor_s: float = 0.0, shrink: float = 0.5,
                 grow: float = 0.25):
        super().__init__()
        if not 0.0 < slo_target <= 1.0:
            raise ValueError(f"slo_target must be in (0, 1], "
                             f"got {slo_target}")
        if not 0.0 < shrink < 1.0:
            raise ValueError(f"shrink must be in (0, 1), got {shrink}")
        if not 0.0 < grow <= 1.0:
            raise ValueError(f"grow must be in (0, 1], got {grow}")
        bounds = (dict(max_queue) if isinstance(max_queue, Mapping)
                  else None)
        base = default_queue if bounds is not None else int(max_queue)
        for b in ([base] + list(bounds.values() if bounds else [])):
            if not (isinstance(b, int) and b >= 1 and math.isfinite(b)):
                raise ValueError(f"queue bounds must be ints >= 1, "
                                 f"got {b!r}")
        if not 1 <= min_queue <= base:
            raise ValueError(f"min_queue must be in [1, {base}], "
                             f"got {min_queue}")
        self.slo_target = slo_target
        self._bounds = bounds
        self._base_bound = base
        self.min_queue = min_queue
        self.window_floor_s = max(0.0, window_floor_s)
        self.shrink = shrink
        self.grow = grow
        self._window = 0.0

    def bind(self, server: "PipelineServer") -> None:
        super().bind(server)
        if not server._has_slo_target():
            raise ValueError(
                "AdaptivePolicy needs an SLO target to sense against: "
                "set slo_s on the server or on at least one tenant")
        self._window = server.batch_window_s

    def reset(self) -> None:
        self._window = self.server.batch_window_s

    # -- sensors --------------------------------------------------------------

    def _stats_for(self, tenant: Optional[str]):
        if tenant is not None:
            per_tenant = getattr(self.server, "tenant_stats", None)
            if per_tenant and tenant in per_tenant:
                return per_tenant[tenant]
        return self.server.stats

    def _attainment(self, tenant: Optional[str] = None) -> Optional[float]:
        """Recent-window SLO attainment, or None when the sensor has no
        signal yet (no SLO configured or no completed requests)."""
        summary = self._stats_for(tenant).recent_summary()
        if summary["n"] == 0:
            return None
        return summary["attainment"]  # None when no SLO configured

    # -- actuators ------------------------------------------------------------

    def window_s(self) -> float:
        base = self.server.batch_window_s
        attainment = self._attainment()
        if attainment is not None:
            if attainment < self.slo_target:
                self._window = max(self.window_floor_s,
                                   self._window * self.shrink)
            else:
                self._window = min(base, self._window + self.grow * base)
        return self._window

    def queue_bound(self, tenant: Optional[str]) -> int:
        """Effective admitted-queue bound for ``tenant`` right now:
        the configured bound, scaled down proportionally to the
        tenant's recent attainment shortfall (floored at
        ``min_queue``)."""
        base = self._base_bound
        if self._bounds is not None and tenant in self._bounds:
            base = self._bounds[tenant]
        attainment = self._attainment(tenant)
        if attainment is None or attainment >= self.slo_target:
            return base
        return max(self.min_queue,
                   int(base * attainment / self.slo_target))

    def admit(self, *, tenant: Optional[str], priority: int,
              inflight: int) -> AdmissionDecision:
        if inflight >= self.server.max_inflight:
            # global saturation stays backpressure (blocking submitters
            # wait) — the per-tenant bound below is the shedding layer
            return AdmissionDecision.wait(GLOBAL_INFLIGHT)
        if self.server._queued_for(tenant) < self.queue_bound(tenant):
            return ADMIT
        queued = self.server._queue_snapshot(tenant)
        if queued:
            # priority eviction: shed the lowest-priority queued request
            # (youngest among equals — the oldest has waited longest) if
            # the incoming one outranks it; otherwise shed the arrival
            victim = min(queued, key=lambda tk: (tk.priority, -tk.rid))
            if victim.priority < priority:
                return AdmissionDecision.admit_evicting(victim)
        return AdmissionDecision.shed_now(TENANT_QUEUE)

    def snapshot(self) -> Dict[str, Any]:
        snap: Dict[str, Any] = {
            "window_s": self._window,
            "slo_target": self.slo_target,
            "min_queue": self.min_queue,
        }
        order = getattr(self.server, "_order", None)
        if order:
            snap["queue_bounds"] = {name: self.queue_bound(name)
                                    for name in order}
        else:
            snap["queue_bound"] = self.queue_bound(None)
        return snap
