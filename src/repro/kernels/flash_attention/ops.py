"""Jit'd public wrapper around the flash-attention Pallas kernel.

Handles layout (B,S,H,Hd) <-> kernel layout (B,Kh,G,S,Hd), sequence padding
to block multiples, and head_dim padding to a 128 multiple (MXU lane width).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (
    DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q, flash_attention_gqa)


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k",
                     "interpret"))
def flash_attention(
    q: jax.Array,  # (B, S, H, Hd)
    k: jax.Array,  # (B, S, K, Hd)
    v: jax.Array,  # (B, S, K, Hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: float = 0.0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    b, s, h, hd = q.shape
    kh = k.shape[2]
    if kh <= 0 or h % kh != 0:
        raise ValueError(
            f"flash_attention: heads axis invalid — q has {h} heads, k/v "
            f"have {kh} kv-heads; GQA needs heads % kv_heads == 0")
    if block_q <= 0 or block_k <= 0:
        raise ValueError(
            f"flash_attention: block shape must be positive, got "
            f"block_q={block_q}, block_k={block_k}")
    g = h // kh

    # kernel layout: (B, Kh, G, S, Hd) for q; (B, Kh, S, Hd) for k/v
    qk = q.reshape(b, s, kh, g, hd).transpose(0, 2, 3, 1, 4)
    kk = k.transpose(0, 2, 1, 3)
    vk = v.transpose(0, 2, 1, 3)

    # pad head_dim to MXU lane multiple and seq to block multiple
    hd_pad = max(128, ((hd + 127) // 128) * 128)
    if hd_pad != hd:
        qk = _pad_to(qk, 4, hd_pad)
        kk = _pad_to(kk, 3, hd_pad)
        vk = _pad_to(vk, 3, hd_pad)
    bq = min(block_q, max(s, 8))
    bk = min(block_k, max(s, 8))
    s_pad = max(((s + bq - 1) // bq) * bq, ((s + bk - 1) // bk) * bk)
    if s_pad != s:
        qk = _pad_to(qk, 3, s_pad)
        kk = _pad_to(kk, 2, s_pad)
        vk = _pad_to(vk, 2, s_pad)

    # scale uses the TRUE head_dim, not the padded one
    out = flash_attention_gqa(
        qk, kk, vk,
        causal=causal,
        window=int(window or 0),
        softcap=softcap,
        block_q=bq,
        block_k=bk,
        interpret=interpret,
        scale=hd ** -0.5,
    )
    out = out[:, :, :, :s, :hd]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, hd)
