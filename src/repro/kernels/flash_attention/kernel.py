"""Pallas TPU flash-attention kernel (blockwise online softmax).

TPU-native design notes (vs. a CUDA port):
- tiling is MXU-aligned: q/k blocks are (block_q, head_dim) x (block_k,
  head_dim) with head_dim padded to a multiple of 128 by the wrapper;
- the kv loop is the innermost *sequential* grid dimension — on TPU, grid
  steps that revisit the same output block execute in order on one core, so
  the online-softmax running state (m, l, acc) lives in VMEM scratch across
  grid steps instead of registers;
- GQA is expressed through BlockSpec index maps: the kv BlockSpec ignores
  the q-head-group grid coordinate, so kv tiles are fetched once per kv head
  (never materialized H/K times in HBM);
- causal and sliding-window masking prune whole kv blocks via ``pl.when``
  (the MXU never sees fully-masked tiles).

Supports: causal masking, sliding-window (gemma local layers), attention
logit softcap (gemma2/grok-1), GQA/MQA.
"""

from __future__ import annotations

import functools
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512


def _flash_kernel(
    q_ref, k_ref, v_ref,  # (1,1,1,bq,hd), (1,1,bk,hd), (1,1,bk,hd)
    o_ref,                # (1,1,1,bq,hd)
    m_ref, l_ref, acc_ref,  # scratch: (bq,1), (bq,1), (bq,hd) fp32
    *,
    scale: float,
    causal: bool,
    window: int,          # 0 = unlimited
    softcap: float,
    block_q: int,
    block_k: int,
    seq_len: int,
):
    iq = pl.program_id(3)
    ik = pl.program_id(4)
    nk = pl.num_programs(4)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # block-level pruning: skip kv blocks that are entirely masked
    relevant = True
    if causal:
        relevant = k_start <= q_start + block_q - 1  # some k <= some q
    if window > 0:
        # newest q position minus oldest k position must be < window somewhere:
        # skip when (q_start - (k_start+block_k-1)) >= window
        relevant = jnp.logical_and(
            relevant, q_start - (k_start + block_k - 1) < window)

    @pl.when(relevant)
    def _body():
        q = q_ref[0, 0, 0].astype(jnp.float32)   # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)      # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)      # (bk, hd)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        delta = q_pos - k_pos
        mask = k_pos < seq_len  # padding
        if causal:
            mask = jnp.logical_and(mask, delta >= 0)
        if window > 0:
            mask = jnp.logical_and(mask, delta < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                       # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                    # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)           # (bq, 1)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = l_ref[...]
        denom = jnp.where(denom == 0.0, 1.0, denom)
        o_ref[0, 0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_gqa(
    q: jax.Array,  # (B, Kh, G, S, Hd) — q heads grouped by kv head
    k: jax.Array,  # (B, Kh, S, Hd)
    v: jax.Array,  # (B, Kh, S, Hd)
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
    scale: float = 0.0,  # 0 -> head_dim**-0.5 (pass explicitly when padded)
) -> jax.Array:
    b, kh, g, s, hd = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    nq = pl.cdiv(s, block_q)
    nk = pl.cdiv(s, block_k)
    scale = scale or hd ** -0.5

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, seq_len=s,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, kh, g, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, block_q, hd),
                         lambda b, h, g, iq, ik: (b, h, g, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, g, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, g, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, block_q, hd),
                               lambda b, h, g, iq, ik: (b, h, g, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
