"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def attention_ref(
    q: jax.Array,  # (B, S, H, Hd)
    k: jax.Array,  # (B, S, K, Hd)
    v: jax.Array,  # (B, S, K, Hd)
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    b, s, h, hd = q.shape
    kv = k.shape[2]
    if kv != h:
        k = jnp.repeat(k, h // kv, axis=2)
        v = jnp.repeat(v, h // kv, axis=2)
    scale = hd ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap > 0.0:
        scores = softcap * jnp.tanh(scores / softcap)
    pos = jnp.arange(s)
    delta = pos[:, None] - pos[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= delta >= 0
    if window > 0:
        mask &= delta < window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32)).astype(q.dtype)
