"""Jit'd wrapper: model layout (B,S,H,P) -> kernel layout (B,H,S,P).

This is the routing target of ``ssm.mamba_prefill`` when cfg.use_pallas.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(
    x: jax.Array,    # (B, S, H, P) fp32 — model layout
    dt: jax.Array,   # (B, S, H)
    A: jax.Array,    # (H,)
    Bm: jax.Array,   # (B, S, G, N)
    Cm: jax.Array,   # (B, S, G, N)
    D: jax.Array,    # (H,)
    chunk: int,
    initial_state: Optional[jax.Array] = None,  # (B, H, P, N)
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    g = Bm.shape[2]
    if chunk <= 0:
        raise ValueError(f"ssd_scan: chunk must be positive, got {chunk}")
    if s % chunk != 0:
        raise ValueError(
            f"ssd_scan: seq axis not divisible — seq={s} is not a "
            f"multiple of chunk={chunk}; pad the sequence first (the "
            f"kernel would silently truncate the tail chunk)")
    if g <= 0 or h % g != 0:
        raise ValueError(
            f"ssd_scan: heads axis invalid — x has {h} heads, B/C have "
            f"{g} groups; needs heads % groups == 0")
    h0 = (initial_state if initial_state is not None
          else jnp.zeros((b, h, p, n), jnp.float32))
    y, hf = ssd_scan(
        x.transpose(0, 2, 1, 3),
        dt.transpose(0, 2, 1),
        A,
        Bm.transpose(0, 2, 1, 3),
        Cm.transpose(0, 2, 1, 3),
        D,
        h0.astype(jnp.float32),
        chunk=chunk,
        interpret=interpret,
    )
    return y.transpose(0, 2, 1, 3), hf
