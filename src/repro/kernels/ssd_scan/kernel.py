"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

TPU-native adaptation of the SSD algorithm (arXiv:2405.21060):
- the chunk axis is the innermost *sequential* grid dimension; the running
  (P, N) inter-chunk state lives in VMEM scratch across grid steps (the GPU
  version uses a separate state-passing kernel + global memory round-trip);
- within a chunk, the quadratic "attention" term and the state update are
  MXU matmuls over (Q, N) x (N, Q) and (P, Q) x (Q, N) tiles; Q (chunk) and
  N (state) are sized to 128-multiples by the wrapper;
- per-head scalars A, D index via BlockSpecs (SMEM scalar prefetch on real
  hardware; plain VMEM blocks suffice for interpret-mode validation).

Grid: (batch, heads, chunks). B/C tensors are shared across the heads of a
group — their BlockSpec index map folds h -> h // heads_per_group, so group
tiles are fetched once per group, not per head.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,      # (1,1,Q,P)
    dt_ref,     # (1,1,Q)
    a_ref,      # (1,)
    b_ref,      # (1,1,Q,N)
    c_ref,      # (1,1,Q,N)
    d_ref,      # (1,)
    h0_ref,     # (1,1,P,N)
    y_ref,      # out: (1,1,Q,P)
    hf_ref,     # out: (1,1,P,N)
    state_ref,  # scratch: (P,N) f32
    *,
    chunk: int,
):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)      # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)    # (Q,)
    bm = b_ref[0, 0].astype(jnp.float32)     # (Q, N)
    cm = c_ref[0, 0].astype(jnp.float32)     # (Q, N)
    a_h = a_ref[0].astype(jnp.float32)       # scalar
    d_h = d_ref[0].astype(jnp.float32)

    a = dt * a_h                              # (Q,) log decay
    a_cum = jnp.cumsum(a)

    # intra-chunk quadratic term
    seg = a_cum[:, None] - a_cum[None, :]     # (Q, Q)
    causal = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(causal, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    att = scores * lmat * dt[None, :]
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk contribution from the carried state
    state = state_ref[...]                    # (P, N)
    y_inter = jax.lax.dot_general(cm, state, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y = y + y_inter * jnp.exp(a_cum)[:, None]

    # state update: h <- h * exp(sum a) + sum_j decay_j dt_j x_j B_j^T
    decay_end = jnp.exp(a_cum[-1] - a_cum)    # (Q,)
    xw = x * (dt * decay_end)[:, None]        # (Q, P)
    state_new = state * jnp.exp(a_cum[-1]) + jax.lax.dot_general(
        xw, bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    state_ref[...] = state_new

    y_ref[0, 0] = (y + x * d_h).astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _final():
        hf_ref[0, 0] = state_new.astype(hf_ref.dtype)


def ssd_scan(
    x: jax.Array,    # (B, H, S, P) fp32
    dt: jax.Array,   # (B, H, S)
    A: jax.Array,    # (H,)
    Bm: jax.Array,   # (B, G, S, N)
    Cm: jax.Array,   # (B, G, S, N)
    D: jax.Array,    # (H,)
    h0: jax.Array,   # (B, H, P, N)
    *,
    chunk: int,
    interpret: bool = True,
):
    b, h, s, p = x.shape
    g, n = Bm.shape[1], Bm.shape[3]
    hpg = h // g
    nc = s // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, chunk, n), lambda b, h, c: (b, h // hpg, c, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda b, h, c: (b, h // hpg, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, p, n), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm, D, h0)
