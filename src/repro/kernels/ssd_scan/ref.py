"""Pure-jnp oracle for the SSD scan kernel — sequential state recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(
    x: jax.Array,    # (B, H, S, P)
    dt: jax.Array,   # (B, H, S)
    A: jax.Array,    # (H,)
    Bm: jax.Array,   # (B, G, S, N)
    Cm: jax.Array,   # (B, G, S, N)
    D: jax.Array,    # (H,)
    h0: jax.Array,   # (B, H, P, N)
):
    """Token-by-token recurrence: h_t = h_{t-1} e^{dt_t A} + dt_t x_t B_t^T,
    y_t = C_t . h_t + D x_t. Returns (y (B,H,S,P), final_state)."""
    b, h, s, p = x.shape
    g = Bm.shape[1]
    hpg = h // g
    bexp = jnp.repeat(Bm, hpg, axis=1)  # (B,H,S,N)
    cexp = jnp.repeat(Cm, hpg, axis=1)

    def step(state, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        decay = jnp.exp(dtt * A[None, :])  # (B,H)
        state = (state * decay[:, :, None, None]
                 + jnp.einsum("bhp,bhn,bh->bhpn", xt, bt, dtt))
        y = jnp.einsum("bhn,bhpn->bhp", ct, state) + xt * D[None, :, None]
        return state, y

    xs = (x.transpose(2, 0, 1, 3), dt.transpose(2, 0, 1),
          bexp.transpose(2, 0, 1, 3), cexp.transpose(2, 0, 1, 3))
    final, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.transpose(1, 2, 0, 3).astype(x.dtype), final
