"""Pallas TPU flash-decode kernel: one query position vs a long KV cache.

This is the serving hot spot — decode_32k/long_500k cells stream the KV
cache per step, and §Perf shows the XLA path additionally materializes
expanded/transposed copies. The kernel:

- never expands GQA: the grid iterates (batch, kv-head, kv-blocks) and the
  per-kv-head query group (G = H/K rows) rides in VMEM as a (G, Hd) tile;
- runs online softmax over kv blocks (innermost sequential grid dim) with
  (G,1)/(G,Hd) running max/denominator/accumulator in VMEM scratch — one
  pass over the cache, no (H, S) score tensor in HBM;
- masks by the *dynamic* cache length: ``valid_len`` arrives as a (1,)
  array indexed per block (SMEM scalar prefetch on real hardware).

Supports GQA/MQA, softcap. Ring-buffer local caches use the jnp path (the
ring index arithmetic is cheap at window size).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)

DEFAULT_BLOCK_S = 512


def _decode_kernel(
    len_ref,   # (1,) int32 — number of valid cache entries
    q_ref,     # (1, 1, G, Hd)
    k_ref,     # (1, bs, 1, Hd)
    v_ref,     # (1, bs, 1, Hd)
    o_ref,     # (1, 1, G, Hd)
    m_ref, l_ref, acc_ref,  # scratch: (G,1), (G,1), (G,Hd) fp32
    *,
    scale: float,
    softcap: float,
    block_s: int,
):
    isb = pl.program_id(2)
    nsb = pl.num_programs(2)

    @pl.when(isb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid_len = len_ref[0]
    s_start = isb * block_s

    @pl.when(s_start < valid_len)  # skip fully-invalid cache blocks
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, Hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)    # (bs, Hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        pos = s_start + jax.lax.broadcasted_iota(jnp.int32,
                                                 (q.shape[0], k.shape[0]), 1)
        s = jnp.where(pos < valid_len, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(isb == nsb - 1)
    def _done():
        denom = l_ref[...]
        denom = jnp.where(denom == 0.0, 1.0, denom)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_decode_gqa(
    q: jax.Array,          # (B, K, G, Hd)
    k: jax.Array,          # (B, S, K, Hd)
    v: jax.Array,
    valid_len: jax.Array,  # (1,) int32
    *,
    softcap: float = 0.0,
    block_s: int = DEFAULT_BLOCK_S,
    interpret: bool = True,
    scale: float = 0.0,
) -> jax.Array:
    b, kh, g, hd = q.shape
    s = k.shape[1]
    block_s = min(block_s, s)
    nsb = pl.cdiv(s, block_s)
    scale = scale or hd ** -0.5
    kernel = functools.partial(_decode_kernel, scale=scale, softcap=softcap,
                               block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=(b, kh, nsb),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, i: (0,)),
            pl.BlockSpec((1, 1, g, hd), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, block_s, 1, hd), lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((1, block_s, 1, hd), lambda b, h, i: (b, i, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b, h, i: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(valid_len, q, k, v)
