"""Pure-jnp oracle for flash-decode."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def decode_ref(q, k, v, valid_len, *, softcap: float = 0.0):
    """q: (B,K,G,Hd); k/v: (B,S,K,Hd); valid_len: scalar int.
    Returns (B,K,G,Hd)."""
    b, kh, g, hd = q.shape
    s = k.shape[1]
    scale = hd ** -0.5
    scores = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap > 0.0:
        scores = softcap * jnp.tanh(scores / softcap)
    mask = jnp.arange(s) < valid_len
    scores = jnp.where(mask[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
