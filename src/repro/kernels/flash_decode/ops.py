"""Jit'd wrapper: model layout (B,1,H,Hd) query + (B,S,K,Hd) cache."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.kernel import DEFAULT_BLOCK_S, flash_decode_gqa


@functools.partial(jax.jit,
                   static_argnames=("softcap", "block_s", "interpret"))
def flash_decode(
    q: jax.Array,          # (B, 1, H, Hd)
    k: jax.Array,          # (B, S, K, Hd)
    v: jax.Array,
    valid_len: jax.Array,  # scalar int32
    *,
    softcap: float = 0.0,
    block_s: int = DEFAULT_BLOCK_S,
    interpret: bool = True,
) -> jax.Array:
    b, _, h, hd = q.shape
    kh = k.shape[2]
    if kh <= 0 or h % kh != 0:
        raise ValueError(
            f"flash_decode: heads axis invalid — q has {h} heads, k/v "
            f"cache has {kh} kv-heads; GQA needs heads % kv_heads == 0")
    if block_s <= 0:
        raise ValueError(
            f"flash_decode: block shape must be positive, got "
            f"block_s={block_s}")
    g = h // kh
    qg = q.reshape(b, kh, g, hd)
    # pad head_dim to the MXU lane multiple
    hd_pad = max(128, ((hd + 127) // 128) * 128)
    if hd_pad != hd:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, hd_pad - hd)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, 0), (0, hd_pad - hd)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, hd_pad - hd)))
    s = k.shape[1]
    bs = min(block_s, max(s, 8))
    pad_s = (-s) % bs
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    out = flash_decode_gqa(
        qg, k, v, jnp.asarray(valid_len, jnp.int32).reshape(1),
        softcap=softcap, block_s=bs, interpret=interpret,
        scale=hd ** -0.5)
    return out[..., :hd].reshape(b, 1, h, hd)
