"""Pure-jnp oracle for the fused expert-FFN kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def expert_ffn_ref(x, w_gate, w_up, w_down):
    """x: (G,E,C,D); weights: (E,D,F)/(E,F,D) -> (G,E,C,D)."""
    xf = x.astype(jnp.float32)
    gate = jnp.einsum("gecd,edf->gecf", xf, w_gate.astype(jnp.float32))
    up = jnp.einsum("gecd,edf->gecf", xf, w_up.astype(jnp.float32))
    out = jnp.einsum("gecf,efd->gecd", jax.nn.silu(gate) * up,
                     w_down.astype(jnp.float32))
    return out.astype(x.dtype)
