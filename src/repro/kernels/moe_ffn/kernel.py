"""Pallas TPU kernel: fused per-expert SwiGLU FFN over dispatched tokens.

Operates on the capacity-dispatched layout (G, E, C, D) produced by the MoE
dispatch einsum. The fusion win vs. the three separate XLA einsums is that
the (C, F) gate/up intermediates never round-trip to HBM: for each f-tile we
compute silu(x@Wg_f) * (x@Wu_f) in VMEM and immediately accumulate its
down-projection into a (C, D) fp32 scratch accumulator. HBM traffic drops
from O(C*F) intermediates to just the x/weight tiles.

Grid: (G, E, C-tiles, F-tiles) with the F axis innermost/sequential.
Expert weights index via BlockSpec on the E coordinate — each core streams
only the tiles of the experts it owns (expert-parallel friendly).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _moe_ffn_kernel(
    x_ref,    # (1, 1, bc, D)
    wg_ref,   # (1, D, bf)
    wu_ref,   # (1, D, bf)
    wd_ref,   # (1, bf, D)
    o_ref,    # (1, 1, bc, D)
    acc_ref,  # scratch (bc, D) f32
):
    jf = pl.program_id(3)
    nf = pl.num_programs(3)

    @pl.when(jf == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0, 0].astype(jnp.float32)    # (bc, D)
    wg = wg_ref[0].astype(jnp.float32)     # (D, bf)
    wu = wu_ref[0].astype(jnp.float32)
    wd = wd_ref[0].astype(jnp.float32)     # (bf, D)

    gate = jax.lax.dot_general(x, wg, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    up = jax.lax.dot_general(x, wu, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    h = jax.nn.silu(gate) * up             # (bc, bf) — stays in VMEM
    acc_ref[...] += jax.lax.dot_general(h, wd, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)

    @pl.when(jf == nf - 1)
    def _done():
        o_ref[0, 0] = acc_ref[...].astype(o_ref.dtype)


def moe_expert_ffn(
    x: jax.Array,       # (G, E, C, D) dispatched tokens
    w_gate: jax.Array,  # (E, D, F)
    w_up: jax.Array,    # (E, D, F)
    w_down: jax.Array,  # (E, F, D)
    *,
    block_c: int = 128,
    block_f: int = 512,
    interpret: bool = True,
) -> jax.Array:
    g, e, c, d = x.shape
    f = w_gate.shape[-1]
    block_c = min(block_c, c)
    block_f = min(block_f, f)
    nc = pl.cdiv(c, block_c)
    nf = pl.cdiv(f, block_f)

    return pl.pallas_call(
        _moe_ffn_kernel,
        grid=(g, e, nc, nf),
        in_specs=[
            pl.BlockSpec((1, 1, block_c, d), lambda g, e, ic, jf: (g, e, ic, 0)),
            pl.BlockSpec((1, d, block_f), lambda g, e, ic, jf: (e, 0, jf)),
            pl.BlockSpec((1, d, block_f), lambda g, e, ic, jf: (e, 0, jf)),
            pl.BlockSpec((1, block_f, d), lambda g, e, ic, jf: (e, jf, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_c, d),
                               lambda g, e, ic, jf: (g, e, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((g, e, c, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, d), jnp.float32)],
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
