"""Jit'd wrapper for the fused expert-FFN kernel (pads C/F to tiles)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.moe_ffn.kernel import moe_expert_ffn


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "interpret"))
def expert_ffn(
    x: jax.Array,       # (G, E, C, D)
    w_gate: jax.Array,  # (E, D, F)
    w_up: jax.Array,
    w_down: jax.Array,  # (E, F, D)
    *,
    block_c: int = 128,
    block_f: int = 512,
    interpret: bool = True,
) -> jax.Array:
    g, e, c, d = x.shape
    f = w_gate.shape[-1]
    if block_c <= 0 or block_f <= 0:
        raise ValueError(
            f"moe_ffn: block shape must be positive, got "
            f"block_c={block_c}, block_f={block_f}")
    if w_gate.shape[0] != e or w_gate.shape[1] != d:
        raise ValueError(
            f"moe_ffn: experts axis mismatch — x is (G,E,C,D)="
            f"{x.shape} but w_gate is (E,D,F)={w_gate.shape}")
    bc = min(block_c, max(c, 8))
    bf = min(block_f, max(f, 128))
    c_pad = (-c) % bc
    f_pad = (-f) % bf
    if c_pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, c_pad), (0, 0)))
    if f_pad:
        w_gate = jnp.pad(w_gate, ((0, 0), (0, 0), (0, f_pad)))
        w_up = jnp.pad(w_up, ((0, 0), (0, 0), (0, f_pad)))
        w_down = jnp.pad(w_down, ((0, 0), (0, f_pad), (0, 0)))
    out = moe_expert_ffn(x, w_gate, w_up, w_down,
                         block_c=bc, block_f=bf, interpret=interpret)
    return out[:, :, :c, :]
