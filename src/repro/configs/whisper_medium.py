"""whisper-medium — enc-dec, 24+24L d_model=1024 16H (kv=16) d_ff=4096
vocab=51865. Conv audio frontend is a STUB: inputs are precomputed frame
embeddings (B, 1500, 1024). [arXiv:2212.04356]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,          # decoder layers
    encoder_layers=24,
    encoder_seq_len=1500,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    tie_embeddings=True,
)
