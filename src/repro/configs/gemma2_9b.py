"""gemma2-9b — 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000.
Alternating local(4096-window)/global attention, attn softcap 50, final
logit softcap 30, post-norms, scaled embeddings, head_dim 256.
[arXiv:2408.00118]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    attn_pattern="local_global",
    local_global_ratio=(1, 1),
    local_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norms=True,
    scale_embeddings=True,
    tie_embeddings=True,
)
