"""zamba2-2.7b — 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64. Mamba2 backbone + ONE shared attention+MLP block applied every
6 layers (9 applications, each with its own KV cache). [arXiv:2411.15242]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
    hybrid_attn_every=6,
    tie_embeddings=True,
)
