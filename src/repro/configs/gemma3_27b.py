"""gemma3-27b — 62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144.
5:1 local:global (window 1024), qk-norm, 128k+ context. head_dim 128 per the
released model. [hf:google/gemma-3-27b-pt family]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    attn_pattern="local_global",
    local_global_ratio=(5, 1),
    local_window=1024,
    qk_norm=True,
    post_norms=True,
    scale_embeddings=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
)
