"""internvl2-1b — 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
InternViT frontend is a STUB: inputs include precomputed patch embeddings
(B, 256, 1024) projected into the LM. [arXiv:2404.16821]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    num_patches=256,
    vit_dim=1024,
    rope_theta=1000000.0,
    tie_embeddings=True,
)
