"""Assigned input-shape cells and per-arch applicability.

Four LM shapes (seq_len x global_batch):
  train_4k     4,096 x 256   -> lowers train_step
  prefill_32k  32,768 x 32   -> lowers prefill (inference)
  decode_32k   32,768 x 128  -> lowers serve_step (1 new token, 32k KV cache)
  long_500k    524,288 x 1   -> lowers serve_step; sub-quadratic archs only

``long_500k`` runs for SSM/hybrid archs (state-space decode is O(1)/token)
and for the gemma local:global family (sliding-window layers carry
ring-buffer caches; only the sparse global layers hold the 500k cache). It
is SKIPPED for pure full-attention archs — see DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

SHAPE_NAMES = list(SHAPES.keys())


def skip_reason(cfg: ModelConfig, shape_name: str) -> Optional[str]:
    """None if the (arch, shape) cell is runnable; else why it is skipped."""
    spec = SHAPES[shape_name]
    if spec.name == "long_500k" and not cfg.sub_quadratic:
        return ("pure full-attention arch: 500k-token decode requires "
                "sub-quadratic attention (per assignment)")
    return None


def runnable_cells(cfg: ModelConfig) -> List[str]:
    return [s for s in SHAPE_NAMES if skip_reason(cfg, s) is None]


def all_cells(archs: List[ModelConfig]) -> List[Tuple[str, str]]:
    """Every (arch, shape) pair including skipped ones (callers filter)."""
    return [(c.name, s) for c in archs for s in SHAPE_NAMES]


def cache_len_for(cfg: ModelConfig, spec: ShapeSpec) -> int:
    """Decode cache capacity: the assigned seq_len plus a small headroom,
    rounded up to a 128 multiple for TPU-friendly tiling."""
    extra = 128
    return ((spec.seq_len + extra + 127) // 128) * 128
