"""Architecture registry: the 10 assigned architectures (the model pool M).

``get_config(name)`` returns the full production config;
``get_config(name, reduced=True)`` a small same-family smoke config.
"""

from __future__ import annotations

from typing import Dict, List

from repro.models.config import ModelConfig

from repro.configs.granite_moe_1b import CONFIG as granite_moe_1b
from repro.configs.grok_1_314b import CONFIG as grok_1_314b
from repro.configs.whisper_medium import CONFIG as whisper_medium
from repro.configs.gemma2_9b import CONFIG as gemma2_9b
from repro.configs.llama32_1b import CONFIG as llama32_1b
from repro.configs.gemma3_27b import CONFIG as gemma3_27b
from repro.configs.granite_34b import CONFIG as granite_34b
from repro.configs.mamba2_370m import CONFIG as mamba2_370m
from repro.configs.zamba2_27b import CONFIG as zamba2_27b
from repro.configs.internvl2_1b import CONFIG as internvl2_1b

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        granite_moe_1b, grok_1_314b, whisper_medium, gemma2_9b, llama32_1b,
        gemma3_27b, granite_34b, mamba2_370m, zamba2_27b, internvl2_1b,
    ]
}


def list_archs() -> List[str]:
    return list(ARCHS.keys())


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    cfg = ARCHS[name]
    return cfg.reduced() if reduced else cfg
