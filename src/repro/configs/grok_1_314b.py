"""grok-1-314b — 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2. [hf:xai-org/grok-1; unverified]

grok-1 uses attention-logit tanh capping (30.0) and tied scaled embeddings;
both are modeled. head_dim = 128.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    moe_d_ff=32768,
    vocab_size=131072,
    num_experts=8,
    num_experts_per_tok=2,
    attn_softcap=30.0,
    scale_embeddings=True,
    tie_embeddings=True,
)
