"""Six synthetic workloads mirroring the paper's evaluation suite (§5.1.2).

Each workload generates a seeded document collection with hidden ground
truth (facts embedded as sentences — canonical form carries a literal
``[tag]`` keyword marker; paraphrased form carries ``(alt-tag)`` which only
LLM-simulated operators and embedding samplers can find), the paper's
initial pipeline, and the paper's scoring function.

Scaled for CPU: word counts are ~6x smaller than the originals (CUAD 7.7k
-> 1.2k words etc.); the *structure* (fact density, paraphrase share,
position distribution, tag vocabulary size) mirrors the original tasks.
D = 140 docs split as D_o = 40 (optimization) / D_T = 100 (held-out test),
exactly the paper's split.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, List

from repro.core.models_catalog import DEFAULT_MODEL
from repro.data.documents import Dataset, Document
from repro.engine.operators import make_pipeline

N_SAMPLE = 40
N_TEST = 100


def _rng01(*parts) -> float:
    h = hashlib.blake2s("|".join(str(p) for p in parts).encode()).digest()
    return int.from_bytes(h[:8], "little") / 2**64


def _pick(seq, *parts):
    return seq[int(_rng01(*parts) * len(seq)) % len(seq)]


_NOISE_WORDS = ("routine administrative filing reference section pursuant "
                "thereto standard provision general matter context detail "
                "record entry note update summary report item status").split()


def _noise_sentence(seed, i) -> str:
    n = 8 + int(_rng01(seed, "nl", i) * 10)
    words = [_pick(_NOISE_WORDS, seed, "nw", i, j) for j in range(n)]
    return " ".join(words) + "."


def _fact_sentence(tag: str, value: str, paraphrased: bool,
                   template01: float = 0.0) -> str:
    if paraphrased:
        return f"the record describes a (alt-{tag}) matter involving {value}."
    if template01 < 0.75:
        return f"the record notes a [{tag}] matter involving {value}."
    # minority phrasing: the synthesized regex ('matter involving') misses
    # it, but keyword compression ('[tag]') still keeps the sentence — so
    # code substitution has an imperfect recall ceiling while code
    # compression + LLM extraction remains effective (paper's trade space)
    return f"the record notes a [{tag}] issue regarding {value}."


def _make_doc(seed, doc_idx: int, *, words: int, tags: List[str],
              n_facts: int, paraphrase_rate: float, text_key: str = "text",
              head_bias: float = 0.0, extra: Dict[str, Any] = None
              ) -> Document:
    """Build one document: noise sentences with facts interleaved."""
    n_noise = max(4, words // 12)
    sents = [_noise_sentence((seed, doc_idx), i) for i in range(n_noise)]
    facts = []
    for f in range(n_facts):
        tag = _pick(tags, seed, "tag", doc_idx, f)
        value = f"v{hashlib.blake2s(f'{seed}|{doc_idx}|{f}'.encode()).hexdigest()[:8]}"
        para = _rng01(seed, "para", doc_idx, f) < paraphrase_rate
        pos01 = _rng01(seed, "pos", doc_idx, f)
        if head_bias and _rng01(seed, "hb", doc_idx, f) < head_bias:
            pos01 *= 0.15
        idx = min(int(pos01 * len(sents)), len(sents))
        sents.insert(idx, _fact_sentence(tag, value, para,
                                         _rng01(seed, "tmpl", doc_idx, f)))
        facts.append({"tag": tag, "value": value, "paraphrased": para,
                      "order": f})
    doc = {"id": f"d{doc_idx}", text_key: " ".join(sents), "_facts": facts}
    if extra:
        doc.update(extra)
    return doc


@dataclass
class Workload:
    name: str
    domain: str
    docs: Dataset
    initial_pipeline: Dict[str, Any]
    tags: List[str]
    scorer: Callable[[Dataset, Dataset], float]
    notes: str = ""

    @property
    def sample(self) -> Dataset:  # D_o
        return self.docs[:N_SAMPLE]

    @property
    def test(self) -> Dataset:    # D_T
        return self.docs[N_SAMPLE:N_SAMPLE + N_TEST]

    def score(self, outputs: Dataset, inputs: Dataset) -> float:
        return max(0.0, min(1.0, self.scorer(outputs, inputs)))


# --------------------------------------------------------------------------
# scorers
# --------------------------------------------------------------------------


def _extraction_f1(outputs: Dataset, inputs: Dataset, out_field: str,
                   tags: List[str]) -> float:
    """Span-extraction F1 over (tag, value) pairs (CUAD-style)."""
    truth = {}
    for d in inputs:
        truth[d["id"]] = {(f["tag"], f["value"]) for f in d.get("_facts", [])
                          if f["tag"] in tags}
    tp = fp = fn = 0
    by_id = {d.get("id"): d for d in outputs}
    for did, gold in truth.items():
        d = by_id.get(did, {})
        pred = {(i.get("tag"), i.get("value"))
                for i in (d.get(out_field) or []) if isinstance(i, dict)}
        tp += len(pred & gold)
        fp += len(pred - gold)
        fn += len(gold - pred)
    if tp == 0:
        return 0.0
    p = tp / (tp + fp)
    r = tp / (tp + fn)
    return 2 * p * r / (p + r)


def _kendall_tau(order: List[int]) -> float:
    n = len(order)
    if n < 2:
        return 1.0
    concordant = discordant = 0
    for i in range(n):
        for j in range(i + 1, n):
            if order[i] < order[j]:
                concordant += 1
            else:
                discordant += 1
    total = concordant + discordant
    return (concordant - discordant) / total if total else 1.0


# --------------------------------------------------------------------------
# workload constructors
# --------------------------------------------------------------------------


def cuad(seed: int = 11) -> Workload:
    """Legal clause extraction: 41 clause types, one map over the contract."""
    tags = [f"clause_{i:02d}" for i in range(41)]
    docs = [_make_doc(seed, i, words=1200, tags=tags, n_facts=8,
                      paraphrase_rate=0.3, text_key="contract")
            for i in range(N_SAMPLE + N_TEST)]
    pipeline = make_pipeline("cuad_initial", [{
        "name": "extract_clauses",
        "type": "map",
        "prompt": ("Extract text spans for each of the 41 clause types "
                   "present in {{ input.contract }}."),
        "task_tags": tags,
        "output_schema": {"clauses": "list[{clause_type, text_span}]"},
        "model": DEFAULT_MODEL,
    }])
    return Workload(
        "cuad", "legal", docs, pipeline, tags,
        lambda out, inp: _extraction_f1(out, inp, "clauses", tags),
        notes="41-type clause extraction; F1 on (type, span)")


def game_reviews(seed: int = 23) -> Workload:
    """Long review blobs; extract ordered positive/negative reviews."""
    tags = ["pos_review", "neg_review"]
    docs = [_make_doc(seed, i, words=6000, tags=tags, n_facts=18,
                      paraphrase_rate=0.45, text_key="reviews")
            for i in range(N_SAMPLE + N_TEST)]
    pipeline = make_pipeline("reviews_initial", [{
        "name": "pick_reviews",
        "type": "map",
        "prompt": ("Identify positive and negative reviews in "
                   "{{ input.reviews }} in chronological order."),
        "task_tags": tags,
        "task_breadth": 16,  # sentiment + chronology joint task
        "output_schema": {"picked": "list[{sentiment, quote}]"},
        "model": DEFAULT_MODEL,
    }])

    def score(out: Dataset, inp: Dataset) -> float:
        f1 = _extraction_f1(out, inp, "picked", tags)
        # order component: extracted items should follow document order
        taus, by_id = [], {d.get("id"): d for d in out}
        for d in inp:
            o = by_id.get(d["id"], {})
            order_map = {f["value"]: f["order"] for f in d.get("_facts", [])}
            seq = [order_map[i["value"]] for i in (o.get("picked") or [])
                   if isinstance(i, dict) and i.get("value") in order_map]
            # no correct extractions -> no ordering credit
            taus.append((_kendall_tau(seq) + 1) / 2 if seq else 0.0)
        tau = sum(taus) / len(taus) if taus else 0.0
        return 0.7 * f1 + 0.3 * tau

    return Workload("game_reviews", "consumer", docs, pipeline, tags, score,
                    notes="sentiment extraction + ordering (F1 + tau)")


def blackvault(seed: int = 37) -> Workload:
    """Classify event type per article; aggregate locations per type."""
    event_types = ["ufo", "cryptid", "anomaly", "signal"]
    tags = ["location"]
    docs = []
    for i in range(N_SAMPLE + N_TEST):
        et = _pick(event_types, seed, "et", i)
        d = _make_doc(seed, i, words=900, tags=tags, n_facts=4,
                      paraphrase_rate=0.35, text_key="article",
                      extra={"_event_type": et})
        docs.append(d)
    pipeline = make_pipeline("blackvault_initial", [
        {
            "name": "classify_event",
            "type": "map",
            "prompt": "Classify the event type of {{ input.article }}.",
            "classify": {"classes": event_types, "truth_field": "_event_type",
                         "output_field": "event_type"},
            "task_tags": [],
            "output_schema": {"event_type": "str"},
            "model": DEFAULT_MODEL,
        },
        {
            "name": "aggregate_locations",
            "type": "reduce",
            "reduce_key": "event_type",
            "prompt": ("Aggregate all distinct locations mentioned across "
                       "articles of this event type."),
            "task_tags": ["location"],
            "output_schema": {"locations": "list[str]"},
            "model": DEFAULT_MODEL,
        },
    ])

    def score(out: Dataset, inp: Dataset) -> float:
        # avg recall of distinct location values per event type
        truth: Dict[str, set] = {}
        for d in inp:
            truth.setdefault(d["_event_type"], set()).update(
                f["value"] for f in d["_facts"] if f["tag"] == "location")
        found: Dict[str, set] = {}
        for g in out:
            et = g.get("event_type")
            vals = set()
            for item in (g.get("locations") or []):
                vals.add(item.get("value") if isinstance(item, dict)
                         else str(item))
            found.setdefault(et, set()).update(vals)
        recalls = []
        for et, gold in truth.items():
            if not gold:
                continue
            recalls.append(len(found.get(et, set()) & gold) / len(gold))
        return sum(recalls) / len(recalls) if recalls else 0.0

    return Workload("blackvault", "government", docs, pipeline,
                    ["location"], score,
                    notes="per-type distinct-location recall")


def biodex(seed: int = 41) -> Workload:
    """Biomedical adverse-reaction linking; long papers, heavy paraphrase."""
    tags = ["reaction"]
    docs = [_make_doc(seed, i, words=2500, tags=tags, n_facts=6,
                      paraphrase_rate=0.7, text_key="paper")
            for i in range(N_SAMPLE + N_TEST)]
    pipeline = make_pipeline("biodex_initial", [{
        "name": "rank_reactions",
        "type": "map",
        "prompt": ("Given the full list of 24k adverse drug reactions, "
                   "return a ranked list of reactions discussed in "
                   "{{ input.paper }}."),
        "task_tags": tags,
        "task_breadth": 60,  # 24k-label space -> high intrinsic breadth
        "output_schema": {"reactions": "list[str]"},
        "model": DEFAULT_MODEL,
    }])

    def score(out: Dataset, inp: Dataset) -> float:
        # rank-precision@5
        by_id = {d.get("id"): d for d in out}
        vals = []
        for d in inp:
            gold = {f["value"] for f in d["_facts"]}
            o = by_id.get(d["id"], {})
            pred = [i.get("value") for i in (o.get("reactions") or [])
                    if isinstance(i, dict)][:5]
            denom = min(len(gold), 5)
            vals.append(len(set(pred) & gold) / denom if denom else 0.0)
        return sum(vals) / len(vals) if vals else 0.0

    return Workload("biodex", "biomedical", docs, pipeline, tags, score,
                    notes="RP@5 over reaction linking")


def medec(seed: int = 53) -> Workload:
    """Short clinical notes; detect + locate the medical error."""
    tags = ["med_error"]
    docs = []
    for i in range(N_SAMPLE + N_TEST):
        has_err = _rng01(seed, "he", i) < 0.5
        d = _make_doc(seed, i, words=60, tags=tags,
                      n_facts=1 if has_err else 0, paraphrase_rate=0.3,
                      text_key="note", head_bias=0.5,
                      extra={"_has_error": has_err})
        docs.append(d)
    pipeline = make_pipeline("medec_initial", [{
        "name": "detect_error",
        "type": "map",
        "prompt": ("Detect whether a medical error is present in "
                   "{{ input.note }}; identify the sentence and correct it."),
        "task_tags": tags,
        "task_breadth": 8,   # detect + locate + correct jointly
        "output_schema": {"errors": "list[{flag, sentence}]"},
        "model": DEFAULT_MODEL,
    }])

    def score(out: Dataset, inp: Dataset) -> float:
        by_id = {d.get("id"): d for d in out}
        tp = fp = fn = 0
        loc_hits, loc_total = 0, 0
        for d in inp:
            o = by_id.get(d["id"], {})
            pred_items = [i for i in (o.get("errors") or [])
                          if isinstance(i, dict)]
            pred_flag = len(pred_items) > 0
            if d["_has_error"] and pred_flag:
                tp += 1
            elif pred_flag:
                fp += 1
            elif d["_has_error"]:
                fn += 1
            if d["_has_error"]:
                loc_total += 1
                gold = {f["value"] for f in d["_facts"]}
                if any(i.get("value") in gold for i in pred_items):
                    loc_hits += 1
        f1 = 2 * tp / (2 * tp + fp + fn) if tp else 0.0
        loc = loc_hits / loc_total if loc_total else 0.0
        return 0.5 * f1 + 0.5 * loc

    return Workload("medec", "medical", docs, pipeline, tags, score,
                    notes="error-detection F1 + localization")


def sustainability(seed: int = 67) -> Workload:
    """Filter to sustainability reports, classify sector, summarize
    companies per sector."""
    sectors = ["tech", "health", "energy", "realestate", "finance",
               "retail", "transport", "agri"]
    tags = ["company"]
    docs = []
    for i in range(N_SAMPLE + N_TEST):
        keep = _rng01(seed, "keep", i) < 0.55
        sector = _pick(sectors, seed, "sec", i)
        d = _make_doc(seed, i, words=2000, tags=tags, n_facts=2,
                      paraphrase_rate=0.25, text_key="report",
                      extra={"_keep": keep, "_sector": sector})
        if keep:  # sustainability reports mention the keyword
            d["report"] = "[sustainability] disclosure report. " + d["report"]
        docs.append(d)
    pipeline = make_pipeline("sustainability_initial", [
        {
            "name": "keep_sustainability",
            "type": "filter",
            "prompt": "Is {{ input.report }} a sustainability report?",
            "filter_truth_field": "_keep",
            "output_schema": {"is_sustainability": "bool"},
            "model": DEFAULT_MODEL,
        },
        {
            "name": "classify_sector",
            "type": "map",
            "prompt": "Classify the company's economic sector.",
            "classify": {"classes": sectors, "truth_field": "_sector",
                         "output_field": "sector"},
            "task_tags": [],
            "output_schema": {"sector": "str"},
            "model": DEFAULT_MODEL,
        },
        {
            "name": "sector_summary",
            "type": "reduce",
            "reduce_key": "sector",
            "prompt": ("For each sector, list each company and its key "
                       "sustainability initiatives."),
            "task_tags": ["company"],
            "output_schema": {"companies": "list[str]"},
            "model": DEFAULT_MODEL,
        },
    ])

    def score(out: Dataset, inp: Dataset) -> float:
        truth: Dict[str, set] = {}
        all_gold = set()
        for d in inp:
            if d["_keep"]:
                vals = {f["value"] for f in d["_facts"]}
                truth.setdefault(d["_sector"], set()).update(vals)
                all_gold |= vals
        found: Dict[str, set] = {}
        listed = set()
        for g in out:
            sec = g.get("sector")
            vals = set()
            for item in (g.get("companies") or []):
                vals.add(item.get("value") if isinstance(item, dict)
                         else str(item))
            found.setdefault(sec, set()).update(vals)
            listed |= vals
        recalls = []
        for sec, gold in truth.items():
            if gold:
                recalls.append(len(found.get(sec, set()) & gold) / len(gold))
        recall = sum(recalls) / len(recalls) if recalls else 0.0
        precision = len(listed & all_gold) / len(listed) if listed else 0.0
        return 0.5 * recall + 0.5 * precision

    return Workload("sustainability", "enterprise", docs, pipeline, tags,
                    score, notes="sector company recall + precision")


WORKLOADS = {
    "cuad": cuad,
    "game_reviews": game_reviews,
    "blackvault": blackvault,
    "biodex": biodex,
    "medec": medec,
    "sustainability": sustainability,
}


def load(name: str, seed: int = 0) -> Workload:
    base = WORKLOADS[name]()
    return base
