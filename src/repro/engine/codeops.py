"""Deterministic evaluator for code-powered operators (code_map /
code_filter / code_reduce).

The paper's agent synthesizes arbitrary Python; in this offline framework a
code-powered operator carries a *CodeSpec* — a restricted, declarative
program (regex/keyword/head-tail/aggregation primitives) that the
deterministic evaluator executes. This keeps the paper's two key
properties: code ops cost $0 (no LLM), and their quality depends on how
well surface patterns capture the task (regexes match literal mentions but
miss paraphrases — which is exactly the precision/recall trade the MOAR
agent explores via parameter-sensitive directives).

CodeSpec kinds:
  keyword_filter    {keywords, min_hits}          doc -> bool
  regex_extract     {pattern, window}             doc -> matching sentences (+context)
  keyword_extract   {keywords, window}            doc -> sentences containing keywords
  head_tail         {head, tail}                  doc -> first/last words
  drop_if_false     {field}                       doc -> bool(doc[field])
  count_group       {field}                       docs -> counts + concatenated context
  concat_group      {field, limit}                docs -> concatenation of a field
"""

from __future__ import annotations

import re
from typing import Any, Dict, List

from repro.data.documents import Dataset, Document, doc_text, main_text_key

CodeSpec = Dict[str, Any]

_SENT_SPLIT = re.compile(r"(?<=[.!?])\s+")


def sentences(text: str) -> List[str]:
    return [s for s in _SENT_SPLIT.split(text) if s.strip()]


def run_code_filter(spec: CodeSpec, doc: Document) -> bool:
    kind = spec["kind"]
    if kind == "keyword_filter":
        text = doc_text(doc).lower()
        hits = sum(1 for kw in spec["keywords"] if kw.lower() in text)
        return hits >= spec.get("min_hits", 1)
    if kind == "drop_if_false":
        return bool(doc.get(spec["field"], False))
    if kind == "regex_filter":
        return re.search(spec["pattern"], doc_text(doc), re.I) is not None
    raise ValueError(f"unknown code_filter kind {kind!r}")


def run_code_map(spec: CodeSpec, doc: Document) -> Dict[str, Any]:
    kind = spec["kind"]
    key = spec.get("text_key") or main_text_key(doc)
    text = str(doc.get(key, ""))
    out_key = spec.get("output_key", key)
    if kind == "head_tail":
        words = text.split()
        h, t = spec.get("head", 100), spec.get("tail", 50)
        if len(words) <= h + t:
            return {out_key: text}
        return {out_key: " ".join(words[:h]) + "\n...\n" + " ".join(words[-t:])}
    if kind == "keyword_facts":
        # structured extraction via regex over canonical fact sentences:
        # matches '[tag] matter involving <value>' — precise, but blind to
        # paraphrased facts (the LLM/code quality trade the paper studies)
        items = []
        for tag in spec["tags"]:
            pat = re.compile(r"\[" + re.escape(tag) +
                             r"\] matter involving (v[0-9a-f]{8})", re.I)
            for m in pat.finditer(text):
                items.append({"tag": tag, "value": m.group(1)})
        return {spec["output_field"]: items}
    if kind == "merge_lists":
        merged = []
        for f in spec["fields"]:
            v = doc.get(f) or []
            merged.extend(v if isinstance(v, list) else [v])
        return {spec["output_field"]: merged}
    if kind == "combine_keys":
        parts = [str(doc.get(f, "")) for f in spec["fields"]]
        return {spec["output_field"]: "|".join(parts)}
    if kind == "assign_bucket":
        import hashlib as _h
        b = int(_h.blake2s(str(doc.get("id")).encode()).hexdigest()[:4], 16) \
            % spec["buckets"]
        gval = str(doc.get(spec["group_field"], ""))
        return {spec["output_key"]: f"{gval}|{b}"}
    if kind == "split_bucket_key":
        combined = str(doc.get("_bucket_key", doc.get("id", "")))
        return {spec["output_key"]: combined.split("|")[0]}
    if kind in ("regex_extract", "keyword_extract"):
        sents = sentences(text)
        window = spec.get("window", 0)
        keep = set()
        if kind == "regex_extract":
            pat = re.compile(spec["pattern"], re.I)

            def match(s):
                return pat.search(s) is not None
        else:
            kws = [k.lower() for k in spec["keywords"]]

            def match(s):
                return any(k in s.lower() for k in kws)
        for i, s in enumerate(sents):
            if match(s):
                for j in range(max(0, i - window), min(len(sents), i + window + 1)):
                    keep.add(j)
        kept = [sents[i] for i in sorted(keep)]
        return {out_key: " ".join(kept)}
    raise ValueError(f"unknown code_map kind {kind!r}")


def run_code_reduce(spec: CodeSpec, docs: Dataset) -> Dict[str, Any]:
    kind = spec["kind"]
    if kind == "count_group":
        field = spec["field"]
        counts: Dict[str, int] = {}
        for d in docs:
            vals = d.get(field, [])
            vals = vals if isinstance(vals, list) else [vals]
            for v in vals:
                counts[str(v)] = counts.get(str(v), 0) + 1
        return {f"{field}_counts": counts}
    if kind == "concat_group":
        field = spec["field"]
        limit = spec.get("limit", 50)
        vals: List[str] = []
        for d in docs[:limit]:
            v = d.get(field, "")
            vals.extend(v if isinstance(v, list) else [str(v)])
        return {f"{field}_all": vals}
    raise ValueError(f"unknown code_reduce kind {kind!r}")
