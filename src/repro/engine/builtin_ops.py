"""Built-in operator registrations (paper §2.1, Table 7).

The execution semantics of every Table 7 operator (map, parallel_map,
reduce, filter, resolve, equijoin, unnest, split, gather, sample, extract,
code_map/code_reduce/code_filter), registered into the
``repro.pipeline`` operator registry. Each registration bundles the
type's validation rules, execution function, cost kind (LLM vs. $0), and
rewrite-target metadata; ``Executor.run`` dispatches through the
registry.

Execution functions take ``(executor, op, docs, stats)``. LLM-kind
operators *plan* their backend invocations as a batch of ``OpRequest``s
and hand the whole batch to ``executor.dispatch`` — which consults the
call cache, chunks by the backend's ``preferred_batch_size``, submits
through ``Backend.submit``, retries transient per-request failures, and
charges the paper's cost model into ``stats``. Auxiliary/code operators
never touch the backend.

NOTE: ``backend`` is imported as a module reference, not from-imported:
this module loads during ``repro.pipeline.__init__`` which the backend
module itself triggers, so names must resolve at call time.
"""

from __future__ import annotations

import math
from typing import Any, Dict, List

from repro.analysis import effects as _effects
from repro.data.documents import (Dataset, Document, doc_text,
                                  main_text_key)
from repro.engine import backend as _backend
from repro.engine import codeops
from repro.pipeline.protocols import OpRequest
from repro.pipeline.spec import (KIND_AUX, KIND_CODE, KIND_LLM,
                                 PipelineValidationError, register_operator)

# ---------------------------------------------------------------------------
# per-type validators (rules beyond simple required keys)
# ---------------------------------------------------------------------------


def _validate_reduce(op):
    if "reduce_key" not in op:
        raise PipelineValidationError(f"{op['name']}: reduce needs reduce_key "
                                      "(may be '_all')")


def _validate_sample(op):
    if op.get("method") not in ("random", "bm25", "embedding", "stratified"):
        raise PipelineValidationError(f"{op['name']}: bad sample method")
    if not op.get("size"):
        raise PipelineValidationError(f"{op['name']}: sample needs size")


def _validate_code(op):
    if not op.get("code"):
        raise PipelineValidationError(f"{op['name']}: code op needs CodeSpec")


# ---------------------------------------------------------------------------
# semantic (LLM-invoking) operators
# ---------------------------------------------------------------------------


def _map_request(op, doc) -> OpRequest:
    if op.get("summarize"):
        return OpRequest("summarize", op, doc=doc, key=doc.get("id"))
    if op.get("classify"):
        spec = op["classify"]
        return OpRequest("classify", op, doc=doc, key=doc.get("id"),
                         extra={"classes": spec["classes"],
                                "truth_field": spec["truth_field"]})
    return OpRequest("map", op, doc=doc, key=doc.get("id"))


@register_operator(
    "map", kind=KIND_LLM, required_keys=("prompt", "model", "output_schema"),
    rewrite_tags=("reads_text", "model_bearing", "decomposable"),
    effects=_effects.effects_map,
    description="LLM projection over each document (extraction, "
                "summarization, classification, formatting)")
def exec_map(ex, op, docs: Dataset, stats) -> Dataset:
    reqs = [_map_request(op, d) for d in docs]
    values = ex.dispatch(reqs, stats)
    out = []
    for d, req, v in zip(docs, reqs, values):
        fields = {op["classify"]["output_field"]: v} \
            if req.kind == "classify" else v
        out.append({**d, **fields})
    return out


@register_operator(
    "parallel_map", kind=KIND_LLM,
    required_keys=("prompt", "model", "output_schema"),
    rewrite_tags=("model_bearing", "decomposable"),
    effects=_effects.effects_parallel_map,
    description="independent sub-prompts over each document, merged")
def exec_parallel_map(ex, op, docs: Dataset, stats) -> Dataset:
    out = docs
    for i, sub in enumerate(op["prompts"]):
        sub_op = {**op, **sub, "name": f"{op['name']}.{i}"}
        sub_op.pop("prompts", None)
        out = exec_map(ex, sub_op, out, stats)
    return out


@register_operator(
    "filter", kind=KIND_LLM,
    required_keys=("prompt", "model", "output_schema"),
    validate=None,
    rewrite_tags=("reads_text", "model_bearing", "pushdown"),
    effects=_effects.effects_filter,
    description="LLM predicate keeping/dropping documents")
def exec_filter(ex, op, docs: Dataset, stats) -> Dataset:
    reqs = [OpRequest("filter", op, doc=d, key=d.get("id")) for d in docs]
    keeps = ex.dispatch(reqs, stats)
    return [d for d, keep in zip(docs, keeps) if keep]


@register_operator(
    "reduce", kind=KIND_LLM,
    required_keys=("prompt", "model", "output_schema"),
    validate=_validate_reduce,
    rewrite_tags=("model_bearing", "aggregation"),
    effects=_effects.effects_reduce,
    description="LLM aggregation over groups (reduce_key, '_all' for "
                "whole-collection)")
def exec_reduce(ex, op, docs: Dataset, stats) -> Dataset:
    groups = list(ex._group(docs, op["reduce_key"]).items())
    reqs = [OpRequest("reduce", op, docs=group, key=gkey)
            for gkey, group in groups]
    values = ex.dispatch(reqs, stats)
    out = []
    for (gkey, group), fields in zip(groups, values):
        doc = {"id": f"group_{gkey}", op["reduce_key"]: gkey, **fields}
        if op.get("restore_id"):
            # chunk-merge reduces group by _parent_id and must restore
            # the original document identity (and its hidden truth, for
            # scoring) so downstream scoring matches documents
            doc["id"] = gkey
            src = group[0]
            for k in src:
                if k.startswith("_") and k not in doc:
                    doc[k] = src[k]
            for k, v in src.items():
                if not k.startswith("_") and k not in doc and k != "id":
                    doc[k] = v
        out.append(doc)
    return out


@register_operator(
    "resolve", kind=KIND_LLM, required_keys=("prompt", "model"),
    rewrite_tags=("model_bearing",),
    effects=_effects.effects_resolve,
    description="canonicalize near-duplicate field values across documents")
def exec_resolve(ex, op, docs: Dataset, stats) -> Dataset:
    [out] = ex.dispatch([OpRequest("resolve", op, docs=list(docs),
                                   key="resolve")], stats)
    return out


@register_operator(
    "equijoin", kind=KIND_LLM, required_keys=("prompt", "model"),
    rewrite_tags=("model_bearing",),
    effects=_effects.effects_equijoin,
    description="semantic join of the stream against op['right_docs']")
def exec_equijoin(ex, op, docs: Dataset, stats) -> Dataset:
    reqs = [OpRequest("equijoin", op, doc=d, key=d.get("id")) for d in docs]
    values = ex.dispatch(reqs, stats)
    out = []
    for d, fields in zip(docs, values):
        if fields is not None:
            out.append({**d, **fields})
    return out


@register_operator(
    "extract", kind=KIND_LLM, required_keys=("prompt", "model"),
    rewrite_tags=("reads_text", "model_bearing", "compression"),
    effects=_effects.effects_extract,
    description="LLM document compression: keep fact-bearing line ranges")
def exec_extract(ex, op, docs: Dataset, stats) -> Dataset:
    reqs = [OpRequest("extract", op, doc=d, key=d.get("id")) for d in docs]
    values = ex.dispatch(reqs, stats)
    return [{**d, **fields} for d, fields in zip(docs, values)]


# ---------------------------------------------------------------------------
# auxiliary ($0) operators
# ---------------------------------------------------------------------------


@register_operator(
    "unnest", kind=KIND_AUX, required_keys=("field",),
    effects=_effects.effects_unnest,
    description="explode a list-valued field into one document per element")
def exec_unnest(ex, op, docs: Dataset, stats) -> Dataset:
    fld = op["field"]
    out = []
    for d in docs:
        vals = d.get(fld, [])
        if not isinstance(vals, list):
            out.append(d)
            continue
        for i, v in enumerate(vals):
            nd = {k: w for k, w in d.items() if k != fld}
            nd["id"] = f"{d.get('id')}#{i}"
            if isinstance(v, dict):
                nd.update(v)
            else:
                nd[fld] = v
            out.append(nd)
    return out


@register_operator(
    "split", kind=KIND_AUX, required_keys=("chunk_size",),
    rewrite_tags=("chunker",),
    effects=_effects.effects_split,
    description="split document text into fixed-size word chunks")
def exec_split(ex, op, docs: Dataset, stats) -> Dataset:
    size = op["chunk_size"]  # words
    out = []
    for d in docs:
        key = op.get("text_key") or main_text_key(d)
        words = str(d.get(key, "")).split()
        n = max(1, math.ceil(len(words) / size))
        for i in range(n):
            chunk = " ".join(words[i * size:(i + 1) * size])
            nd = dict(d)
            nd["id"] = f"{d.get('id')}::c{i}"
            nd[key] = chunk
            nd["_parent_id"] = d.get("id")
            nd["_chunk_idx"] = i
            nd["_num_chunks"] = n
            out.append(nd)
    return out


@register_operator(
    "gather", kind=KIND_AUX, rewrite_tags=("chunker",),
    effects=_effects.effects_gather,
    description="widen each chunk with prev/next sibling context")
def exec_gather(ex, op, docs: Dataset, stats) -> Dataset:
    prev_k = op.get("prev", 1)
    next_k = op.get("next", 0)
    by_parent: Dict[Any, List[Document]] = {}
    for d in docs:
        by_parent.setdefault(d.get("_parent_id"), []).append(d)
    out = []
    for _parent, chunks in by_parent.items():
        chunks = sorted(chunks, key=lambda c: c.get("_chunk_idx", 0))
        key = op.get("text_key") or main_text_key(chunks[0])
        texts = [str(c.get(key, "")) for c in chunks]
        for i, c in enumerate(chunks):
            parts = []
            for j in range(max(0, i - prev_k), i):
                parts.append(texts[j])
            parts.append(texts[i])
            for j in range(i + 1, min(len(chunks), i + 1 + next_k)):
                parts.append(texts[j])
            nd = dict(c)
            nd[key] = " ".join(parts)
            out.append(nd)
    return out


def _score_doc(method: str, text: str, keywords: List[str]) -> float:
    t = text.lower()
    score = 0.0
    for kw in keywords:
        score += t.count(f"[{kw.lower()}]")
        if method == "embedding":
            score += 0.8 * t.count(f"(alt-{kw.lower()})")
    return score


@register_operator(
    "sample", kind=KIND_AUX, validate=_validate_sample,
    rewrite_tags=("sampler",),
    effects=_effects.effects_sample,
    description="keep a subset per group (random/bm25/embedding/stratified)")
def exec_sample(ex, op, docs: Dataset, stats) -> Dataset:
    method = op["method"]
    size = op["size"]
    group_key = op.get("group_key")
    keywords = op.get("query_keywords", [])

    def pick(cands: Dataset) -> Dataset:
        if len(cands) <= size:
            return list(cands)
        if method == "random" or not keywords:
            idx = sorted(range(len(cands)),
                         key=lambda i: _backend._hash01(
                             ex.seed, "smp", op["name"], cands[i].get("id")))
            return [cands[i] for i in idx[:size]]
        scored = sorted(
            cands,
            key=lambda d: (-_score_doc(method, doc_text(d), keywords),
                           str(d.get("id"))))
        return scored[:size]

    if group_key:
        out = []
        for _, group in ex._group(docs, group_key).items():
            out.extend(pick(group))
        return out
    return pick(docs)


# ---------------------------------------------------------------------------
# code-powered ($0) operators
# ---------------------------------------------------------------------------


@register_operator(
    "code_map", kind=KIND_CODE, validate=_validate_code,
    rewrite_tags=("code",),
    effects=_effects.effects_code_map,
    description="deterministic CodeSpec projection per document")
def exec_code_map(ex, op, docs: Dataset, stats) -> Dataset:
    return [{**d, **codeops.run_code_map(op["code"], d)} for d in docs]


@register_operator(
    "code_filter", kind=KIND_CODE, validate=_validate_code,
    rewrite_tags=("code", "pushdown"),
    effects=_effects.effects_code_filter,
    description="deterministic CodeSpec predicate per document")
def exec_code_filter(ex, op, docs: Dataset, stats) -> Dataset:
    return [d for d in docs if codeops.run_code_filter(op["code"], d)]


@register_operator(
    "code_reduce", kind=KIND_CODE, validate=_validate_code,
    rewrite_tags=("code", "aggregation"),
    effects=_effects.effects_code_reduce,
    description="deterministic CodeSpec aggregation over groups")
def exec_code_reduce(ex, op, docs: Dataset, stats) -> Dataset:
    key = op.get("reduce_key", "_all")
    out = []
    for gkey, group in ex._group(docs, key).items():
        fields = codeops.run_code_reduce(op["code"], group)
        doc = {"id": f"group_{gkey}", key: gkey, **fields}
        if op.get("restore_id"):
            doc["id"] = gkey
            for k, v in group[0].items():
                if k not in doc and k != "id":
                    doc[k] = v
        out.append(doc)
    return out
