"""Pipeline executor: runs an operator sequence over a document collection.

Implements the execution semantics of every operator in Table 7 (map,
parallel_map, reduce, filter, resolve, equijoin, unnest, split, gather,
sample, extract, code_map/code_reduce/code_filter) against a pluggable
backend (SimBackend / JaxBackend).

Returns (output documents, ExecutionStats) where stats carry the paper's
cost model: $ cost = sum over LLM ops of tokens x model token price; code
and auxiliary operators cost $0 (paper §2.3). A latency estimate (calls x
size-dependent per-call latency / worker parallelism) feeds Table 9.

Transient-failure injection (``fail_prob``) exercises the optimizer's
error-handling path (paper §4.3.3) in tests.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.data.documents import (Dataset, Document, doc_text,
                                  main_text_key, word_count)
from repro.engine import codeops
from repro.engine.backend import SimBackend, Usage, _hash01
from repro.engine.operators import (LLM_TYPES, PipelineConfig,
                                    validate_pipeline)


class TransientLLMError(RuntimeError):
    """Simulated API failure (rate limit / outage)."""


@dataclass
class ExecutionStats:
    cost: float = 0.0
    llm_calls: int = 0
    in_tokens: int = 0
    out_tokens: int = 0
    latency_s: float = 0.0
    per_op: Dict[str, float] = field(default_factory=dict)

    def charge(self, op_name: str, model: str, usage: Usage, backend):
        c = backend.usage_cost(model, usage) if model else 0.0
        self.cost += c
        self.llm_calls += usage.calls
        self.in_tokens += usage.in_tokens
        self.out_tokens += usage.out_tokens
        self.per_op[op_name] = self.per_op.get(op_name, 0.0) + c
        if model:
            from repro.core.models_catalog import catalog
            n_act = catalog()[model].active_params
            self.latency_s += usage.calls * (0.15 + 2e-12 * n_act *
                                             usage.out_tokens)


class Executor:
    def __init__(self, backend, *, fail_prob: float = 0.0, seed: int = 0,
                 workers: int = 3):
        self.backend = backend
        self.fail_prob = fail_prob
        self.seed = seed
        self.workers = workers
        self._run_counter = 0  # transient failures vary across retries

    # -- failure injection ---------------------------------------------------

    def _maybe_fail(self, op, key):
        if self.fail_prob > 0 and \
                _hash01(self.seed, "apifail", self._run_counter,
                        op.get("name"), key) < self.fail_prob:
            raise TransientLLMError(
                f"simulated API failure in {op.get('name')}")

    # -- per-type execution ---------------------------------------------------

    def _exec_map(self, op, docs: Dataset, stats) -> Dataset:
        out = []
        for d in docs:
            self._maybe_fail(op, d.get("id"))
            if op.get("summarize"):
                fields, usage = self.backend.run_summarize(op, d)
            elif op.get("classify"):
                spec = op["classify"]
                label, usage = self.backend.run_classify(
                    op, d, spec["classes"], spec["truth_field"])
                fields = {spec["output_field"]: label}
            else:
                fields, usage = self.backend.run_map(op, d)
            stats.charge(op["name"], op["model"], usage, self.backend)
            out.append({**d, **fields})
        return out

    def _exec_parallel_map(self, op, docs: Dataset, stats) -> Dataset:
        out = docs
        for i, sub in enumerate(op["prompts"]):
            sub_op = {**op, **sub, "name": f"{op['name']}.{i}"}
            sub_op.pop("prompts", None)
            out = self._exec_map(sub_op, out, stats)
        return out

    def _exec_filter(self, op, docs: Dataset, stats) -> Dataset:
        out = []
        for d in docs:
            self._maybe_fail(op, d.get("id"))
            keep, usage = self.backend.run_filter(op, d)
            stats.charge(op["name"], op["model"], usage, self.backend)
            if keep:
                out.append(d)
        return out

    def _group(self, docs: Dataset, key: str) -> Dict[Any, Dataset]:
        if key == "_all":
            return {"_all": list(docs)}
        groups: Dict[Any, Dataset] = {}
        for d in docs:
            groups.setdefault(d.get(key), []).append(d)
        return groups

    def _exec_reduce(self, op, docs: Dataset, stats) -> Dataset:
        out = []
        for gkey, group in self._group(docs, op["reduce_key"]).items():
            self._maybe_fail(op, gkey)
            fields, usage = self.backend.run_reduce(op, group)
            stats.charge(op["name"], op["model"], usage, self.backend)
            doc = {"id": f"group_{gkey}", op["reduce_key"]: gkey, **fields}
            if op.get("restore_id"):
                # chunk-merge reduces group by _parent_id and must restore
                # the original document identity (and its hidden truth, for
                # scoring) so downstream scoring matches documents
                doc["id"] = gkey
                src = group[0]
                for k in src:
                    if k.startswith("_") and k not in doc:
                        doc[k] = src[k]
                for k, v in src.items():
                    if not k.startswith("_") and k not in doc and k != "id":
                        doc[k] = v
            out.append(doc)
        return out

    def _exec_resolve(self, op, docs: Dataset, stats) -> Dataset:
        self._maybe_fail(op, "resolve")
        out, usage = self.backend.run_resolve(op, docs)
        stats.charge(op["name"], op["model"], usage, self.backend)
        return out

    def _exec_equijoin(self, op, docs: Dataset, stats) -> Dataset:
        """Semantic join of the stream against op['right_docs']."""
        right = op.get("right_docs", [])
        fld_l, fld_r = op["left_field"], op["right_field"]
        out = []
        for d in docs:
            lval = str(d.get(fld_l, "")).lower()
            best = None
            for r in right:
                if str(r.get(fld_r, "")).lower() == lval:
                    best = r
                    break
            usage = Usage(in_tokens=40 * max(len(right), 1), out_tokens=4,
                          calls=1)
            stats.charge(op["name"], op["model"], usage, self.backend)
            if best is not None:
                out.append({**d, **{f"right_{k}": v for k, v in best.items()
                                    if not k.startswith("_")}})
        return out

    def _exec_unnest(self, op, docs: Dataset, stats) -> Dataset:
        fld = op["field"]
        out = []
        for d in docs:
            vals = d.get(fld, [])
            if not isinstance(vals, list):
                out.append(d)
                continue
            for i, v in enumerate(vals):
                nd = {k: w for k, w in d.items() if k != fld}
                nd["id"] = f"{d.get('id')}#{i}"
                if isinstance(v, dict):
                    nd.update(v)
                else:
                    nd[fld] = v
                out.append(nd)
        return out

    def _exec_split(self, op, docs: Dataset, stats) -> Dataset:
        size = op["chunk_size"]  # words
        out = []
        for d in docs:
            key = op.get("text_key") or main_text_key(d)
            words = str(d.get(key, "")).split()
            n = max(1, math.ceil(len(words) / size))
            for i in range(n):
                chunk = " ".join(words[i * size:(i + 1) * size])
                nd = dict(d)
                nd["id"] = f"{d.get('id')}::c{i}"
                nd[key] = chunk
                nd["_parent_id"] = d.get("id")
                nd["_chunk_idx"] = i
                nd["_num_chunks"] = n
                out.append(nd)
        return out

    def _exec_gather(self, op, docs: Dataset, stats) -> Dataset:
        prev_k = op.get("prev", 1)
        next_k = op.get("next", 0)
        by_parent: Dict[Any, List[Document]] = {}
        for d in docs:
            by_parent.setdefault(d.get("_parent_id"), []).append(d)
        out = []
        for parent, chunks in by_parent.items():
            chunks = sorted(chunks, key=lambda c: c.get("_chunk_idx", 0))
            key = op.get("text_key") or main_text_key(chunks[0])
            texts = [str(c.get(key, "")) for c in chunks]
            for i, c in enumerate(chunks):
                parts = []
                for j in range(max(0, i - prev_k), i):
                    parts.append(texts[j])
                parts.append(texts[i])
                for j in range(i + 1, min(len(chunks), i + 1 + next_k)):
                    parts.append(texts[j])
                nd = dict(c)
                nd[key] = " ".join(parts)
                out.append(nd)
        return out

    def _score_doc(self, method: str, text: str, keywords: List[str]) -> float:
        t = text.lower()
        score = 0.0
        for kw in keywords:
            score += t.count(f"[{kw.lower()}]")
            if method == "embedding":
                score += 0.8 * t.count(f"(alt-{kw.lower()})")
        return score

    def _exec_sample(self, op, docs: Dataset, stats) -> Dataset:
        method = op["method"]
        size = op["size"]
        group_key = op.get("group_key")
        keywords = op.get("query_keywords", [])

        def pick(cands: Dataset) -> Dataset:
            if len(cands) <= size:
                return list(cands)
            if method == "random" or not keywords:
                idx = sorted(range(len(cands)),
                             key=lambda i: _hash01(self.seed, "smp", op["name"],
                                                   cands[i].get("id")))
                return [cands[i] for i in idx[:size]]
            scored = sorted(
                cands,
                key=lambda d: (-self._score_doc(method, doc_text(d), keywords),
                               str(d.get("id"))))
            return scored[:size]

        if group_key:
            out = []
            for _, group in self._group(docs, group_key).items():
                out.extend(pick(group))
            return out
        return pick(docs)

    def _exec_extract(self, op, docs: Dataset, stats) -> Dataset:
        out = []
        for d in docs:
            self._maybe_fail(op, d.get("id"))
            fields, usage = self.backend.run_extract(op, d)
            stats.charge(op["name"], op["model"], usage, self.backend)
            out.append({**d, **fields})
        return out

    def _exec_code_map(self, op, docs: Dataset, stats) -> Dataset:
        return [{**d, **codeops.run_code_map(op["code"], d)} for d in docs]

    def _exec_code_filter(self, op, docs: Dataset, stats) -> Dataset:
        return [d for d in docs if codeops.run_code_filter(op["code"], d)]

    def _exec_code_reduce(self, op, docs: Dataset, stats) -> Dataset:
        key = op.get("reduce_key", "_all")
        out = []
        for gkey, group in self._group(docs, key).items():
            fields = codeops.run_code_reduce(op["code"], group)
            doc = {"id": f"group_{gkey}", key: gkey, **fields}
            if op.get("restore_id"):
                doc["id"] = gkey
                for k, v in group[0].items():
                    if k not in doc and k != "id":
                        doc[k] = v
            out.append(doc)
        return out

    # -- entry point -----------------------------------------------------------

    def run(self, pipeline: PipelineConfig, docs: Dataset
            ) -> Tuple[Dataset, ExecutionStats]:
        validate_pipeline(pipeline)
        self._run_counter += 1
        stats = ExecutionStats()
        cur = list(docs)
        for op in pipeline["operators"]:
            t = op["type"]
            handler = getattr(self, f"_exec_{t}", None)
            if handler is None:
                raise ValueError(f"no executor for op type {t!r}")
            cur = handler(op, cur, stats)
        stats.latency_s /= max(self.workers, 1)
        return cur, stats
