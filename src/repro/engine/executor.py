"""Pipeline executor: runs an operator sequence over a document collection.

Execution dispatches through the ``repro.pipeline`` operator registry
(engine/builtin_ops.py registers the Table 7 set: map, parallel_map,
reduce, filter, resolve, equijoin, unnest, split, gather, sample, extract,
code_map/code_reduce/code_filter) against a pluggable backend satisfying
the batched ``Backend`` protocol (SimBackend / JaxBackend; v1 per-document
backends are auto-wrapped in a ``LegacyBackendAdapter``), checked at
construction. Custom operator types execute without touching this file:
one ``@register_operator`` call is the whole integration.

Each LLM-kind operator plans its invocations as a batch of ``OpRequest``s
and hands them to :meth:`Executor.dispatch`, which

- answers requests from the content-addressed **call cache** — keyed on
  (backend fingerprint, op fingerprint, doc fingerprint) — replaying the
  recorded usage so measured cost/latency are unchanged while the backend
  is not re-invoked (the cache tier below the pipeline-hash cache in
  ``core/search.py``: rewrites sharing a prefix with an evaluated
  candidate only pay for the changed suffix);
- splits the remainder into ``preferred_batch_size`` chunks for
  ``Backend.submit`` (JaxBackend routes chunks through the continuous
  batcher in ``serving/scheduler.py``);
- retries individual requests on ``TransientLLMError`` instead of
  aborting the whole pipeline evaluation; a request that keeps failing
  for ``max_attempts`` attempts aborts the evaluation as before.

Returns (output documents, ExecutionStats) where stats carry the paper's
cost model: $ cost = sum over LLM ops of tokens x model token price; code
and auxiliary operators cost $0 (paper §2.3). Latency (calls x
size-dependent per-call latency / worker parallelism) feeds Table 8/9 and
is recorded per operator alongside cost, calls, and token counts in
``per_op``.

Transient-failure injection (``fail_prob``) exercises the optimizer's
error-handling path (paper §4.3.3) in tests.
"""

from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core.models_catalog import catalog
from repro.data.documents import Dataset, content_hash
from repro.engine import builtin_ops  # noqa: F401 — registers Table 7 ops
from repro.engine.backend import Usage, _hash01
from repro.engine.operators import validate_pipeline
from repro.pipeline.model import PipelineLike, as_config
from repro.pipeline.protocols import (OpRequest, TransientBackendError,
                                      backend_fingerprint, batch_hint,
                                      check_backend, is_deterministic)
from repro.pipeline.spec import operator_spec


class TransientLLMError(TransientBackendError):
    """Simulated API failure (rate limit / outage)."""


@dataclass
class OpStats:
    """Per-operator accounting: cost, latency, calls, and token counts."""

    cost: float = 0.0
    latency_s: float = 0.0
    calls: int = 0
    in_tokens: int = 0
    out_tokens: int = 0


@dataclass
class ExecutionStats:
    cost: float = 0.0
    llm_calls: int = 0
    in_tokens: int = 0
    out_tokens: int = 0
    latency_s: float = 0.0
    retries: int = 0
    per_op: Dict[str, OpStats] = field(default_factory=dict)

    def charge(self, op_name: str, model: str, usage: Usage, backend):
        c = backend.usage_cost(model, usage) if model else 0.0
        self.cost += c
        self.llm_calls += usage.calls
        self.in_tokens += usage.in_tokens
        self.out_tokens += usage.out_tokens
        entry = self.per_op.setdefault(op_name, OpStats())
        entry.cost += c
        entry.calls += usage.calls
        entry.in_tokens += usage.in_tokens
        entry.out_tokens += usage.out_tokens
        if model:
            n_act = catalog()[model].active_params
            lat = usage.calls * (0.15 + 2e-12 * n_act * usage.out_tokens)
            self.latency_s += lat
            entry.latency_s += lat

    def merge(self, other: "ExecutionStats") -> "ExecutionStats":
        """Accumulate ``other`` into this record (suffix-cache
        accounting: stats of a cached prefix + a re-executed suffix sum
        to the full-pipeline measurement). Returns self for chaining."""
        self.cost += other.cost
        self.llm_calls += other.llm_calls
        self.in_tokens += other.in_tokens
        self.out_tokens += other.out_tokens
        self.latency_s += other.latency_s
        self.retries += other.retries
        for name, st in other.per_op.items():
            entry = self.per_op.setdefault(name, OpStats())
            entry.cost += st.cost
            entry.latency_s += st.latency_s
            entry.calls += st.calls
            entry.in_tokens += st.in_tokens
            entry.out_tokens += st.out_tokens
        return self

    def per_op_cost(self) -> Dict[str, float]:
        return {k: v.cost for k, v in self.per_op.items()}

    def per_op_latency(self) -> Dict[str, float]:
        return {k: v.latency_s for k, v in self.per_op.items()}


class CallCache:
    """Content-addressed memo of backend invocations: the evaluation
    cache tier *below* the pipeline-hash cache.

    Key: (backend fingerprint, request kind, op config minus ``name``,
    document content) — a deterministic backend returns the same
    (value, usage) for that key regardless of which candidate pipeline
    asked, so near-identical candidates sharing a prefix with an already
    evaluated one only re-execute the changed suffix. Entries are deep-
    copied on store AND hit: cached state never aliases live documents,
    so a downstream operator mutating a merged field in place (legal for
    third-party registered ops) cannot poison the cache. Whole-corpus
    payloads (UNCACHED_KINDS) never enter, keeping copies small.
    """

    def __init__(self):
        self.data: Dict[str, Tuple[Any, Any]] = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self.data)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def lookup(self, key: str) -> Optional[Tuple[Any, Any]]:
        entry = self.data.get(key)
        if entry is None:
            self.misses += 1
            return None
        self.hits += 1
        return copy.deepcopy(entry)

    def store(self, key: str, value: Any, usage: Any) -> None:
        self.data[key] = copy.deepcopy((value, usage))

    def clear(self) -> None:
        self.data.clear()
        self.hits = 0
        self.misses = 0


def evaluation_cache_stats(pipeline_hits: int, pipeline_entries: int,
                           call_cache: CallCache) -> Dict[str, Any]:
    """The two-tier cache report every optimizer exposes as
    ``SearchResult.cache_stats``: pipeline-hash tier (identical
    candidates) + content-addressed call tier (shared-prefix reuse)."""
    return {
        "pipeline_cache_hits": pipeline_hits,
        "pipeline_cache_entries": pipeline_entries,
        "call_cache_hits": call_cache.hits,
        "call_cache_misses": call_cache.misses,
        "call_cache_hit_rate": call_cache.hit_rate,
        "call_cache_entries": len(call_cache),
    }


_UNSET = object()

# request kinds the call cache skips: a resolve request carries the whole
# document stream and returns it rewritten, so fingerprinting the key
# costs as much as the (cheap) call and the cached value would hold a
# second copy of the corpus
UNCACHED_KINDS = frozenset({"resolve"})


class Executor:
    def __init__(self, backend, *, fail_prob: float = 0.0, seed: int = 0,
                 workers: int = 3, call_cache: Optional[CallCache] = None,
                 max_attempts: int = 3):
        self.backend = check_backend(backend)
        self.batch_hint = batch_hint(self.backend)
        self.fail_prob = fail_prob
        self.seed = seed
        self.workers = workers
        self.max_attempts = max(1, max_attempts)
        self.call_cache = call_cache if call_cache is not None else CallCache()
        self._cache_enabled = is_deterministic(self.backend)
        self._backend_fp = backend_fingerprint(self.backend)
        self._run_counter = 0  # transient failures vary across retries

    # -- shared infrastructure for operator implementations -------------------

    def _group(self, docs: Dataset, key: str) -> Dict[Any, Dataset]:
        if key == "_all":
            return {"_all": list(docs)}
        groups: Dict[Any, Dataset] = {}
        for d in docs:
            groups.setdefault(d.get(key), []).append(d)
        return groups

    # -- batched request dispatch ---------------------------------------------

    def _fails(self, req: OpRequest, attempt: int) -> bool:
        return self.fail_prob > 0 and \
            _hash01(self.seed, "apifail", self._run_counter,
                    req.op.get("name"), req.key, attempt) < self.fail_prob

    def _cache_key(self, req: OpRequest, op_fps: Dict[int, str]) -> str:
        # the op config is shared by every request of a batch (and can
        # embed large payloads, e.g. equijoin right_docs): hash it once
        # per dispatch, memoized by object identity
        op_fp = op_fps.get(id(req.op))
        if op_fp is None:
            op_fp = content_hash({k: v for k, v in req.op.items()
                                  if k != "name"})
            op_fps[id(req.op)] = op_fp
        payload = req.docs if req.kind in ("reduce", "resolve") else req.doc
        return content_hash([self._backend_fp, req.kind, op_fp, payload,
                             req.extra])

    def _charge(self, req: OpRequest, usage, stats: ExecutionStats) -> None:
        stats.charge(req.op["name"], req.op.get("model", ""), usage,
                     self.backend)

    def dispatch(self, requests: List[OpRequest], stats: ExecutionStats
                 ) -> List[Any]:
        """Answer a batch of operator invocations, in request order.

        Cache hits replay their recorded usage into ``stats`` (measured
        cost is a property of the pipeline, not of who paid for the
        call); misses go to ``Backend.submit`` in ``preferred_batch_size``
        chunks, with per-request retry of transient failures. Charging
        happens in request order after every request resolved, so the
        float accumulation is bit-identical whatever the hit pattern,
        chunking, or retry schedule. Raises ``TransientLLMError`` only
        after a request exhausts ``max_attempts``.
        """
        results: List[Any] = [_UNSET] * len(requests)
        usages: List[Any] = [None] * len(requests)
        keys: List[Optional[str]] = [None] * len(requests)
        op_fps: Dict[int, str] = {}
        pending: List[int] = []
        for i, req in enumerate(requests):
            if self._cache_enabled and req.kind not in UNCACHED_KINDS:
                keys[i] = self._cache_key(req, op_fps)
                hit = self.call_cache.lookup(keys[i])
                if hit is not None:
                    results[i], usages[i] = hit
                    continue
            pending.append(i)

        attempt = 0
        while pending:
            retry: List[int] = []
            live: List[int] = []
            for i in pending:
                if self._fails(requests[i], attempt):
                    if attempt + 1 >= self.max_attempts:
                        raise TransientLLMError(
                            f"simulated API failure in "
                            f"{requests[i].op.get('name')} "
                            f"(gave up after {attempt + 1} attempts)")
                    retry.append(i)
                    continue
                live.append(i)
            for start in range(0, len(live), self.batch_hint):
                chunk = live[start:start + self.batch_hint]
                try:
                    outs = self.backend.submit([requests[i] for i in chunk])
                except TransientBackendError as e:
                    # the documented contract allows raising instead of
                    # returning OpResult(error=...): retry the chunk
                    if attempt + 1 >= self.max_attempts:
                        raise TransientLLMError(
                            f"backend failure persisted for "
                            f"{attempt + 1} attempts: {e}") from e
                    retry.extend(chunk)
                    continue
                if len(outs) != len(chunk):
                    raise RuntimeError(
                        f"{type(self.backend).__name__}.submit returned "
                        f"{len(outs)} results for {len(chunk)} requests")
                for i, res in zip(chunk, outs):
                    if res.error is not None:
                        if isinstance(res.error, TransientBackendError):
                            if attempt + 1 < self.max_attempts:
                                retry.append(i)
                                continue
                            # normalize so optimizer error handlers
                            # (except TransientLLMError) keep working
                            raise TransientLLMError(
                                f"{requests[i].op.get('name')}: transient "
                                f"backend failure persisted for "
                                f"{attempt + 1} attempts: {res.error}"
                            ) from res.error
                        raise res.error
                    # backends may omit usage for free operations
                    usage = res.usage if res.usage is not None else Usage()
                    if keys[i] is not None:
                        self.call_cache.store(keys[i], res.value, usage)
                    results[i] = res.value
                    usages[i] = usage
            stats.retries += len(retry)
            pending = retry
            attempt += 1

        assert not any(r is _UNSET for r in results)
        for req, usage in zip(requests, usages):
            self._charge(req, usage, stats)
        return results

    # -- entry point -----------------------------------------------------------

    def run(self, pipeline: PipelineLike, docs: Dataset
            ) -> Tuple[Dataset, ExecutionStats]:
        config = as_config(pipeline)
        validate_pipeline(config)
        self._run_counter += 1
        stats = ExecutionStats()
        cur = list(docs)
        for op in config["operators"]:
            spec = operator_spec(op["type"])
            cur = spec.execute(self, op, cur, stats)
        # worker parallelism scales wall-clock latency; keep per-op entries
        # in the same units so they sum to latency_s
        stats.latency_s /= max(self.workers, 1)
        for entry in stats.per_op.values():
            entry.latency_s /= max(self.workers, 1)
        return cur, stats
