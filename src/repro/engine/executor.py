"""Pipeline executor: runs an operator sequence over a document collection.

Execution dispatches through the ``repro.pipeline`` operator registry
(engine/builtin_ops.py registers the Table 7 set: map, parallel_map,
reduce, filter, resolve, equijoin, unnest, split, gather, sample, extract,
code_map/code_reduce/code_filter) against a pluggable backend satisfying
the ``Backend`` protocol (SimBackend / JaxBackend), checked at
construction. Custom operator types execute without touching this file:
one ``@register_operator`` call is the whole integration.

Returns (output documents, ExecutionStats) where stats carry the paper's
cost model: $ cost = sum over LLM ops of tokens x model token price; code
and auxiliary operators cost $0 (paper §2.3). Latency (calls x
size-dependent per-call latency / worker parallelism) feeds Table 8/9 and
is recorded per operator alongside cost and calls in ``per_op``.

Transient-failure injection (``fail_prob``) exercises the optimizer's
error-handling path (paper §4.3.3) in tests.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Tuple

from repro.core.models_catalog import catalog
from repro.data.documents import Dataset
from repro.engine import builtin_ops  # noqa: F401 — registers Table 7 ops
from repro.engine.backend import Usage, _hash01
from repro.engine.operators import validate_pipeline
from repro.pipeline.model import PipelineLike, as_config
from repro.pipeline.protocols import batch_hint, check_backend
from repro.pipeline.spec import operator_spec


class TransientLLMError(RuntimeError):
    """Simulated API failure (rate limit / outage)."""


@dataclass
class OpStats:
    """Per-operator accounting: cost, latency, and LLM call count."""

    cost: float = 0.0
    latency_s: float = 0.0
    calls: int = 0


@dataclass
class ExecutionStats:
    cost: float = 0.0
    llm_calls: int = 0
    in_tokens: int = 0
    out_tokens: int = 0
    latency_s: float = 0.0
    per_op: Dict[str, OpStats] = field(default_factory=dict)

    def charge(self, op_name: str, model: str, usage: Usage, backend):
        c = backend.usage_cost(model, usage) if model else 0.0
        self.cost += c
        self.llm_calls += usage.calls
        self.in_tokens += usage.in_tokens
        self.out_tokens += usage.out_tokens
        entry = self.per_op.setdefault(op_name, OpStats())
        entry.cost += c
        entry.calls += usage.calls
        if model:
            n_act = catalog()[model].active_params
            lat = usage.calls * (0.15 + 2e-12 * n_act * usage.out_tokens)
            self.latency_s += lat
            entry.latency_s += lat

    def per_op_cost(self) -> Dict[str, float]:
        return {k: v.cost for k, v in self.per_op.items()}

    def per_op_latency(self) -> Dict[str, float]:
        return {k: v.latency_s for k, v in self.per_op.items()}


class Executor:
    def __init__(self, backend, *, fail_prob: float = 0.0, seed: int = 0,
                 workers: int = 3):
        self.backend = check_backend(backend)
        self.batch_hint = batch_hint(backend)
        self.fail_prob = fail_prob
        self.seed = seed
        self.workers = workers
        self._run_counter = 0  # transient failures vary across retries

    # -- shared infrastructure for operator implementations -------------------

    def _maybe_fail(self, op, key):
        if self.fail_prob > 0 and \
                _hash01(self.seed, "apifail", self._run_counter,
                        op.get("name"), key) < self.fail_prob:
            raise TransientLLMError(
                f"simulated API failure in {op.get('name')}")

    def _group(self, docs: Dataset, key: str) -> Dict[Any, Dataset]:
        if key == "_all":
            return {"_all": list(docs)}
        groups: Dict[Any, Dataset] = {}
        for d in docs:
            groups.setdefault(d.get(key), []).append(d)
        return groups

    # -- entry point -----------------------------------------------------------

    def run(self, pipeline: PipelineLike, docs: Dataset
            ) -> Tuple[Dataset, ExecutionStats]:
        config = as_config(pipeline)
        validate_pipeline(config)
        self._run_counter += 1
        stats = ExecutionStats()
        cur = list(docs)
        for op in config["operators"]:
            spec = operator_spec(op["type"])
            cur = spec.execute(self, op, cur, stats)
        # worker parallelism scales wall-clock latency; keep per-op entries
        # in the same units so they sum to latency_s
        stats.latency_s /= max(self.workers, 1)
        for entry in stats.per_op.values():
            entry.latency_s /= max(self.workers, 1)
        return cur, stats
