"""Pipeline executor: runs an operator sequence over a document collection.

Execution dispatches through the ``repro.pipeline`` operator registry
(engine/builtin_ops.py registers the Table 7 set: map, parallel_map,
reduce, filter, resolve, equijoin, unnest, split, gather, sample, extract,
code_map/code_reduce/code_filter) against a pluggable backend satisfying
the batched ``Backend`` protocol (SimBackend / JaxBackend; v1 per-document
backends are auto-wrapped in a ``LegacyBackendAdapter``), checked at
construction. Custom operator types execute without touching this file:
one ``@register_operator`` call is the whole integration.

Each LLM-kind operator plans its invocations as a batch of ``OpRequest``s
and hands them to :meth:`Executor.dispatch`, which

- answers requests from the content-addressed **call cache** — keyed on
  (backend fingerprint, op fingerprint, doc fingerprint) — replaying the
  recorded usage so measured cost/latency are unchanged while the backend
  is not re-invoked (the cache tier below the pipeline-hash cache in
  ``core/search.py``: rewrites sharing a prefix with an evaluated
  candidate only pay for the changed suffix);
- splits the remainder into ``preferred_batch_size`` chunks for
  ``Backend.submit`` (JaxBackend routes chunks through the continuous
  batcher in ``serving/scheduler.py``);
- retries individual requests on ``TransientLLMError`` instead of
  aborting the whole pipeline evaluation; a request that keeps failing
  for ``max_attempts`` attempts aborts the evaluation as before.

Cross-pipeline dispatch sessions (:meth:`Executor.run_session`) evaluate
several candidate pipelines as one *stage-aligned* round: each pipeline
runs its operator loop on its own worker thread, but every ``dispatch``
call posts its request batch to the session coordinator instead of the
backend. When every live evaluation of the group is either blocked in
``dispatch`` or finished, the coordinator merges the posted batches — in
canonical (job index, request index) order — into shared
``Backend.submit`` chunks, so sibling candidates' LLM calls ride one
request stream instead of dispatching one pipeline at a time. The
two-tier cache semantics are preserved: all cache/stat mutation happens
on the coordinator thread under the ``CallCache`` lock, lookups run in
canonical order, and identical in-flight requests are answered by one
backend call. Failure injection is keyed per job (each job owns the
``run`` counter it would have drawn sequentially), so a session is
bit-identical to evaluating its jobs one after another with ``run`` —
``workers`` only changes wall-clock, never results.

Returns (output documents, ExecutionStats) where stats carry the paper's
cost model: $ cost = sum over LLM ops of tokens x model token price; code
and auxiliary operators cost $0 (paper §2.3). Latency (calls x
size-dependent per-call latency / worker parallelism) feeds Table 8/9 and
is recorded per operator alongside cost, calls, and token counts in
``per_op``.

Transient-failure injection (``fail_prob``) exercises the optimizer's
error-handling path (paper §4.3.3) in tests.
"""

from __future__ import annotations

import copy
import threading
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple, Union

from repro.core.models_catalog import catalog
from repro.data.documents import Dataset, content_hash
from repro.engine import builtin_ops  # noqa: F401 — registers Table 7 ops
from repro.engine.backend import Usage, _hash01
from repro.engine.operators import validate_pipeline
from repro.pipeline.model import PipelineLike, as_config
from repro.pipeline.protocols import (OpRequest, TransientBackendError,
                                      backend_fingerprint, batch_hint,
                                      check_backend, is_deterministic)
from repro.pipeline.spec import operator_spec


class TransientLLMError(TransientBackendError):
    """Simulated API failure (rate limit / outage)."""


@dataclass
class OpStats:
    """Per-operator accounting: cost, latency, calls, and token counts."""

    cost: float = 0.0
    latency_s: float = 0.0
    calls: int = 0
    in_tokens: int = 0
    out_tokens: int = 0


@dataclass
class ExecutionStats:
    cost: float = 0.0
    llm_calls: int = 0
    in_tokens: int = 0
    out_tokens: int = 0
    latency_s: float = 0.0
    retries: int = 0
    per_op: Dict[str, OpStats] = field(default_factory=dict)

    def charge(self, op_name: str, model: str, usage: Usage, backend):
        c = backend.usage_cost(model, usage) if model else 0.0
        self.cost += c
        self.llm_calls += usage.calls
        self.in_tokens += usage.in_tokens
        self.out_tokens += usage.out_tokens
        entry = self.per_op.setdefault(op_name, OpStats())
        entry.cost += c
        entry.calls += usage.calls
        entry.in_tokens += usage.in_tokens
        entry.out_tokens += usage.out_tokens
        if model:
            n_act = catalog()[model].active_params
            lat = usage.calls * (0.15 + 2e-12 * n_act * usage.out_tokens)
            self.latency_s += lat
            entry.latency_s += lat

    def merge(self, other: "ExecutionStats") -> "ExecutionStats":
        """Accumulate ``other`` into this record (suffix-cache
        accounting: stats of a cached prefix + a re-executed suffix sum
        to the full-pipeline measurement). Returns self for chaining."""
        self.cost += other.cost
        self.llm_calls += other.llm_calls
        self.in_tokens += other.in_tokens
        self.out_tokens += other.out_tokens
        self.latency_s += other.latency_s
        self.retries += other.retries
        for name, st in other.per_op.items():
            entry = self.per_op.setdefault(name, OpStats())
            entry.cost += st.cost
            entry.latency_s += st.latency_s
            entry.calls += st.calls
            entry.in_tokens += st.in_tokens
            entry.out_tokens += st.out_tokens
        return self

    def per_op_cost(self) -> Dict[str, float]:
        return {k: v.cost for k, v in self.per_op.items()}

    def per_op_latency(self) -> Dict[str, float]:
        return {k: v.latency_s for k, v in self.per_op.items()}


class CallCache:
    """Content-addressed memo of backend invocations: the evaluation
    cache tier *below* the pipeline-hash cache.

    Key: (backend fingerprint, request kind, op config minus ``name``,
    document content) — a deterministic backend returns the same
    (value, usage) for that key regardless of which candidate pipeline
    asked, so near-identical candidates sharing a prefix with an already
    evaluated one only re-execute the changed suffix. Entries are deep-
    copied on store AND hit: cached state never aliases live documents,
    so a downstream operator mutating a merged field in place (legal for
    third-party registered ops) cannot poison the cache. Whole-corpus
    payloads (UNCACHED_KINDS) never enter, keeping copies small.

    ``max_entries`` bounds the memo as an LRU (hits refresh recency;
    evictions are counted) — long serving episodes would otherwise grow
    it without limit. The default stays unbounded: a budgeted search
    touches a bounded key set, and eviction would perturb its hit
    accounting.

    Subclass hooks (``repro.cache.PersistentCallCache`` implements them
    against a durable store; all three are invoked with ``_lock`` held,
    so implementations must not re-enter this cache):

    - ``_backing_lookup(key)``: consulted on a memory miss; a returned
      entry is promoted into memory and counted as a hit;
    - ``_miss(key)``: called after both tiers missed (replay mode turns
      this into a hard failure);
    - ``_persist(key, entry, kind)``: called after every ``store``.

    Class attributes executors consult: ``cache_all_kinds`` overrides
    the ``UNCACHED_KINDS`` skip list (recordings must cover every
    request); ``persistent`` makes the executor demand a *stable*
    backend fingerprint (``backend_fingerprint(require_stable=True)``) —
    an instance-token key would poison a shared store.
    """

    cache_all_kinds = False
    persistent = False

    def __init__(self, max_entries: Optional[int] = None):
        if max_entries is not None and max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.data: "OrderedDict[str, Tuple[Any, Any]]" = OrderedDict()
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # dispatch sessions funnel all cache traffic through the single
        # coordinator thread, but the cache object is also shared across
        # executors (MOAR + baselines) — guard mutation regardless
        self._lock = threading.Lock()

    def __len__(self) -> int:
        return len(self.data)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    # -- subclass hooks (called with ``_lock`` held) -------------------------

    def _backing_lookup(self, key: str) -> Optional[Tuple[Any, Any]]:
        return None

    def _miss(self, key: str) -> None:
        pass

    def _persist(self, key: str, entry: Tuple[Any, Any],
                 kind: Optional[str]) -> None:
        pass

    # -- core ----------------------------------------------------------------

    def _insert(self, key: str, entry: Tuple[Any, Any]) -> None:
        self.data[key] = entry
        self.data.move_to_end(key)
        if self.max_entries is not None:
            while len(self.data) > self.max_entries:
                self.data.popitem(last=False)
                self.evictions += 1

    def lookup(self, key: str) -> Optional[Tuple[Any, Any]]:
        with self._lock:
            entry = self.data.get(key)
            if entry is not None:
                self.data.move_to_end(key)
                self.hits += 1
                return copy.deepcopy(entry)
            entry = self._backing_lookup(key)
            if entry is not None:
                self._insert(key, entry)
                self.hits += 1
                return copy.deepcopy(entry)
            self.misses += 1
            self._miss(key)
            return None

    def store(self, key: str, value: Any, usage: Any,
              kind: Optional[str] = None) -> None:
        entry = copy.deepcopy((value, usage))
        with self._lock:
            self._insert(key, entry)
            self._persist(key, entry, kind)

    def counters(self) -> Dict[str, int]:
        """Snapshot of the integer counters (serving episodes diff two
        snapshots to report per-episode cache activity)."""
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "entries": len(self.data)}

    def clear(self) -> None:
        with self._lock:
            self.data.clear()
            self.hits = 0
            self.misses = 0
            self.evictions = 0


def evaluation_cache_stats(pipeline_hits: int, pipeline_entries: int,
                           call_cache: CallCache) -> Dict[str, Any]:
    """The two-tier cache report every optimizer exposes as
    ``SearchResult.cache_stats``: pipeline-hash tier (identical
    candidates) + content-addressed call tier (shared-prefix reuse).
    A persistent call cache contributes a third, durable tier's
    accounting under ``"persistent"``."""
    stats = {
        "pipeline_cache_hits": pipeline_hits,
        "pipeline_cache_entries": pipeline_entries,
        "call_cache_hits": call_cache.hits,
        "call_cache_misses": call_cache.misses,
        "call_cache_hit_rate": call_cache.hit_rate,
        "call_cache_entries": len(call_cache),
        "call_cache_evictions": call_cache.evictions,
    }
    persistent = getattr(call_cache, "persistent_stats", None)
    if callable(persistent):
        stats["persistent"] = persistent()
    return stats


_UNSET = object()

# request kinds the call cache skips: a resolve request carries the whole
# document stream and returns it rewritten, so fingerprinting the key
# costs as much as the (cheap) call and the cached value would hold a
# second copy of the corpus
UNCACHED_KINDS = frozenset({"resolve"})


@dataclass
class SessionResult:
    """Outcome of one job of a dispatch session: the output documents and
    stats of a successful evaluation, or the ``TransientLLMError`` that
    aborted it (``docs`` is None then)."""

    docs: Optional[Dataset]
    stats: ExecutionStats
    error: Optional[Exception] = None


class SessionAborted(RuntimeError):
    """The dispatch-session coordinator died before answering this job's
    stage barrier — a placeholder; the coordinator's own exception is the
    root cause and replaces this one in ``SessionResult.error``."""


@dataclass
class _SessionJob:
    """One pipeline evaluation inside a dispatch session. Doubles as the
    job thread's channel to the coordinator: ``dispatch`` posts request
    batches here and blocks until the merged stage answers them."""

    index: int
    config: Any
    docs: Dataset
    run_no: int
    tag: Optional[str] = None
    stats: ExecutionStats = field(default_factory=ExecutionStats)
    out: Optional[Dataset] = None
    exc: Optional[Exception] = None
    done: bool = False
    cond: Any = None
    # stage barrier state (guarded by ``cond``)
    posted: Optional[Tuple[List[OpRequest], ExecutionStats]] = None
    reply: Optional[List[Any]] = None
    reply_exc: Optional[Exception] = None
    aborted: bool = False  # coordinator died; fail fast instead of parking
    # merged-stage scratch (coordinator thread only)
    stage_results: List[Any] = field(default_factory=list)
    stage_usages: List[Any] = field(default_factory=list)
    stage_keys: List[Optional[str]] = field(default_factory=list)
    stage_error: Optional[Exception] = None

    def rendezvous(self, requests: List[OpRequest], stats: ExecutionStats
                   ) -> List[Any]:
        """Called from the job thread inside ``dispatch``: park the batch
        at the stage barrier and wait for the coordinator's answer."""
        with self.cond:
            if self.aborted:
                raise SessionAborted("dispatch session aborted")
            self.posted = (requests, stats)
            self.reply = None
            self.reply_exc = None
            self.cond.notify_all()
            while self.posted is not None and not self.aborted:
                self.cond.wait()
            if self.aborted:
                self.posted = None
                raise SessionAborted("dispatch session aborted")
            if self.reply_exc is not None:
                exc = self.reply_exc
                self.reply_exc = None
                raise exc
            reply = self.reply
            self.reply = None
            return reply


@dataclass
class _StageEntry:
    """One unanswered request of a merged stage, with its per-entry retry
    attempt counter (a follower promoted to leader restarts at 0)."""

    job: _SessionJob
    li: int
    req: OpRequest
    key: Optional[str]
    attempt: int = 0


class Executor:
    def __init__(self, backend, *, fail_prob: float = 0.0, seed: int = 0,
                 workers: int = 3, call_cache: Optional[CallCache] = None,
                 max_attempts: int = 3):
        self.backend = check_backend(backend)
        self.batch_hint = batch_hint(self.backend)
        self.fail_prob = fail_prob
        self.seed = seed
        self.workers = workers
        self.max_attempts = max(1, max_attempts)
        self.call_cache = call_cache if call_cache is not None else CallCache()
        self._cache_enabled = is_deterministic(self.backend)
        # record/replay caches memoize every request kind (a recording
        # must cover the whole session); a persistent cache additionally
        # demands a declared-stable backend fingerprint — an instance
        # token would never hit across sessions and would silently
        # poison a shared store with unreachable records
        self._cache_all_kinds = bool(getattr(self.call_cache,
                                             "cache_all_kinds", False))
        self._backend_fp = backend_fingerprint(
            self.backend,
            require_stable=bool(getattr(self.call_cache, "persistent",
                                        False)))
        bind = getattr(self.call_cache, "bind_backend", None)
        if callable(bind) and self._cache_enabled:
            bind(self._backend_fp)
        self._run_counter = 0  # transient failures vary across retries
        # per-thread evaluation context: the run number owning the current
        # op loop (failure-injection key) and, inside a dispatch session,
        # the job whose coordinator channel dispatch() must post to
        self._tl = threading.local()
        # set by run_session for the duration of a session: how many of a
        # merged stage's chunks may be in flight at once (backends opt in
        # via ``concurrent_submit``)
        self._session_concurrency = 1
        # observability for benchmarks / SearchResult.parallel_stats
        self.dispatch_stats: Dict[str, int] = {
            "submit_calls": 0, "sessions": 0, "session_jobs": 0,
            "merged_stages": 0, "merged_requests": 0,
        }
        # per-tag session accounting (run_session(tags=...)): multi-tenant
        # serving hosts label each job with its tenant so coalescing
        # evidence can be attributed per tenant. Mutated only on the
        # session caller thread (the coordinator runs inline there).
        self.tag_stats: Dict[str, Dict[str, int]] = {}

    # -- shared infrastructure for operator implementations -------------------

    def _group(self, docs: Dataset, key: str) -> Dict[Any, Dataset]:
        if key == "_all":
            return {"_all": list(docs)}
        groups: Dict[Any, Dataset] = {}
        for d in docs:
            groups.setdefault(d.get(key), []).append(d)
        return groups

    # -- batched request dispatch ---------------------------------------------

    def _fails(self, req: OpRequest, attempt: int,
               run_no: Optional[int] = None) -> bool:
        if run_no is None:
            run_no = getattr(self._tl, "run_no", self._run_counter)
        return self.fail_prob > 0 and \
            _hash01(self.seed, "apifail", run_no,
                    req.op.get("name"), req.key, attempt) < self.fail_prob

    def _cacheable(self, kind: str) -> bool:
        """Whether the call cache handles this request kind: the
        ``UNCACHED_KINDS`` skip list applies unless the cache itself
        (record/replay modes) claims every kind."""
        return self._cache_enabled and (
            self._cache_all_kinds or kind not in UNCACHED_KINDS)

    def _cache_key(self, req: OpRequest, op_fps: Dict[int, str]) -> str:
        # the op config is shared by every request of a batch (and can
        # embed large payloads, e.g. equijoin right_docs): hash it once
        # per dispatch, memoized by object identity
        op_fp = op_fps.get(id(req.op))
        if op_fp is None:
            op_fp = content_hash({k: v for k, v in req.op.items()
                                  if k != "name"})
            op_fps[id(req.op)] = op_fp
        payload = req.docs if req.kind in ("reduce", "resolve") else req.doc
        return content_hash([self._backend_fp, req.kind, op_fp, payload,
                             req.extra])

    def _charge(self, req: OpRequest, usage, stats: ExecutionStats) -> None:
        stats.charge(req.op["name"], req.op.get("model", ""), usage,
                     self.backend)

    def _count_tag(self, tag: Optional[str], key: str, n: int = 1) -> None:
        if tag is None:
            return
        entry = self.tag_stats.setdefault(tag, {"jobs": 0, "requests": 0})
        entry[key] = entry.get(key, 0) + n

    def dispatch(self, requests: List[OpRequest], stats: ExecutionStats
                 ) -> List[Any]:
        """Answer a batch of operator invocations, in request order.

        Cache hits replay their recorded usage into ``stats`` (measured
        cost is a property of the pipeline, not of who paid for the
        call); misses go to ``Backend.submit`` in ``preferred_batch_size``
        chunks, with per-request retry of transient failures. Charging
        happens in request order after every request resolved, so the
        float accumulation is bit-identical whatever the hit pattern,
        chunking, or retry schedule. Raises ``TransientLLMError`` only
        after a request exhausts ``max_attempts``.

        Inside a dispatch session (``run_session``) this call instead
        posts the batch to the session coordinator, which merges it with
        the sibling evaluations' batches at the same stage boundary.
        """
        job = getattr(self._tl, "channel", None)
        if job is not None:
            return job.rendezvous(requests, stats)
        # inline (single-member-group) session jobs dispatch directly on
        # the caller thread; attribute their request volume to the tag
        self._count_tag(getattr(self._tl, "tag", None), "requests",
                        len(requests))
        results: List[Any] = [_UNSET] * len(requests)
        usages: List[Any] = [None] * len(requests)
        keys: List[Optional[str]] = [None] * len(requests)
        op_fps: Dict[int, str] = {}
        pending: List[int] = []
        for i, req in enumerate(requests):
            if self._cacheable(req.kind):
                keys[i] = self._cache_key(req, op_fps)
                hit = self.call_cache.lookup(keys[i])
                if hit is not None:
                    results[i], usages[i] = hit
                    continue
            pending.append(i)

        attempt = 0
        while pending:
            retry: List[int] = []
            live: List[int] = []
            for i in pending:
                if self._fails(requests[i], attempt):
                    if attempt + 1 >= self.max_attempts:
                        raise TransientLLMError(
                            f"simulated API failure in "
                            f"{requests[i].op.get('name')} "
                            f"(gave up after {attempt + 1} attempts)")
                    retry.append(i)
                    continue
                live.append(i)
            for start in range(0, len(live), self.batch_hint):
                chunk = live[start:start + self.batch_hint]
                try:
                    self.dispatch_stats["submit_calls"] += 1
                    outs = self.backend.submit([requests[i] for i in chunk])
                except TransientBackendError as e:
                    # the documented contract allows raising instead of
                    # returning OpResult(error=...): retry the chunk
                    if attempt + 1 >= self.max_attempts:
                        raise TransientLLMError(
                            f"backend failure persisted for "
                            f"{attempt + 1} attempts: {e}") from e
                    retry.extend(chunk)
                    continue
                if len(outs) != len(chunk):
                    raise RuntimeError(
                        f"{type(self.backend).__name__}.submit returned "
                        f"{len(outs)} results for {len(chunk)} requests")
                for i, res in zip(chunk, outs):
                    if res.error is not None:
                        if isinstance(res.error, TransientBackendError):
                            if attempt + 1 < self.max_attempts:
                                retry.append(i)
                                continue
                            # normalize so optimizer error handlers
                            # (except TransientLLMError) keep working
                            raise TransientLLMError(
                                f"{requests[i].op.get('name')}: transient "
                                f"backend failure persisted for "
                                f"{attempt + 1} attempts: {res.error}"
                            ) from res.error
                        raise res.error
                    # backends may omit usage for free operations
                    usage = res.usage if res.usage is not None else Usage()
                    if keys[i] is not None:
                        self.call_cache.store(keys[i], res.value, usage,
                                              kind=requests[i].kind)
                    results[i] = res.value
                    usages[i] = usage
            stats.retries += len(retry)
            pending = retry
            attempt += 1

        assert not any(r is _UNSET for r in results)
        for req, usage in zip(requests, usages):
            self._charge(req, usage, stats)
        return results

    # -- entry point -----------------------------------------------------------

    def _execute_ops(self, config, docs: Dataset, stats: ExecutionStats
                     ) -> Dataset:
        cur = list(docs)
        for op in config["operators"]:
            spec = operator_spec(op["type"])
            cur = spec.execute(self, op, cur, stats)
        # worker parallelism scales wall-clock latency; keep per-op entries
        # in the same units so they sum to latency_s
        stats.latency_s /= max(self.workers, 1)
        for entry in stats.per_op.values():
            entry.latency_s /= max(self.workers, 1)
        return cur

    def run(self, pipeline: PipelineLike, docs: Dataset
            ) -> Tuple[Dataset, ExecutionStats]:
        config = as_config(pipeline)
        validate_pipeline(config)
        self._run_counter += 1
        self._tl.run_no = self._run_counter
        stats = ExecutionStats()
        cur = self._execute_ops(config, docs, stats)
        return cur, stats

    # -- cross-pipeline dispatch session ---------------------------------------

    def run_session(self, jobs: List[Tuple[PipelineLike, Dataset]], *,
                    workers: int = 1, capture_errors: bool = False,
                    tags: Optional[List[Optional[str]]] = None
                    ) -> List["SessionResult"]:
        """Evaluate several pipelines as one batched round.

        With ``workers == 1`` the jobs evaluate one after another —
        sequential dispatch, the reference semantics. With
        ``workers > 1`` the whole set advances *stage-aligned*: every
        evaluation runs its operator loop on its own thread, each
        ``dispatch`` call blocks at the session barrier, and once all
        live evaluations are blocked (or finished) the coordinator
        answers the merged batch through shared ``Backend.submit``
        chunks (:meth:`_process_stage`). ``workers`` caps the backend
        round-trips in flight at once — the transport budget the old
        one-thread-per-candidate design would have used — not the number
        of evaluations advancing together.

        Results are bit-identical to calling :meth:`run` on each job in
        order, for any ``workers``: each job owns the run number it would
        have drawn sequentially (failure injection is keyed by it), all
        cache traffic happens on the coordinator thread in canonical
        (job index, request index) order, and a deterministic backend
        answers a request identically whatever chunk carries it.
        Per-job transient failures come back as ``SessionResult.error``
        (the sibling jobs are unaffected); non-transient errors re-raise
        in the caller after the group drains, exactly as ``run`` would —
        unless ``capture_errors`` is set, in which case *every* failure —
        per-job ones and coordinator-level ones (``Backend.submit``
        raising on the coordinator thread takes its whole group down) —
        is returned as ``SessionResult.error`` so one bad request or one
        dead round trip cannot take down its siblings or the caller (the
        serving layer's isolation contract:
        ``repro.serving.pipeline_server``).

        ``tags`` optionally labels each job (e.g. with its serving
        tenant); per-tag job/request counts accumulate in
        :attr:`tag_stats` so a multi-tenant host can attribute the
        merged dispatch volume per tenant.
        """
        if tags is not None and len(tags) != len(jobs):
            raise ValueError(f"tags length {len(tags)} != jobs "
                             f"length {len(jobs)}")
        configs = []
        for pipeline, _ in jobs:
            config = as_config(pipeline)
            validate_pipeline(config)
            configs.append(config)
        # reserve the run numbers a sequential caller would have drawn
        base = self._run_counter
        self._run_counter += len(jobs)
        self.dispatch_stats["sessions"] += 1
        self.dispatch_stats["session_jobs"] += len(jobs)
        session = [_SessionJob(index=i, config=config, docs=list(docs),
                               run_no=base + i + 1,
                               tag=None if tags is None else tags[i])
                   for i, (config, (_, docs)) in
                   enumerate(zip(configs, jobs))]
        for job in session:
            self._count_tag(job.tag, "jobs")
        # workers=1: strictly sequential. workers>1: one stage-aligned
        # group over the whole set (bounded so a huge batch cannot spawn
        # unbounded stacks), with `workers` submits in flight at once.
        group_size = 1 if workers <= 1 else max(workers,
                                                min(len(session), 64))
        self._session_concurrency = max(1, workers)
        try:
            for start in range(0, len(session), group_size):
                group = session[start:start + group_size]
                if len(group) == 1:
                    self._run_job_inline(group[0],
                                         capture_errors=capture_errors)
                    continue
                try:
                    self._run_group(group)
                except Exception as e:  # noqa: BLE001 — charged per job
                    if not capture_errors:
                        raise
                    # the coordinator died (e.g. Backend.submit raised a
                    # non-transient error): completed jobs keep their
                    # results, every job the abort took down carries the
                    # root cause instead of the SessionAborted
                    # placeholder, and later groups still run
                    for job in group:
                        if job.out is None and (
                                job.exc is None
                                or isinstance(job.exc, SessionAborted)):
                            job.exc = e
        finally:
            self._session_concurrency = 1
        out = []
        for job in session:
            if job.exc is not None and not capture_errors and \
                    not isinstance(job.exc, TransientLLMError):
                raise job.exc
            out.append(SessionResult(docs=job.out, stats=job.stats,
                                     error=job.exc))
        return out

    def _run_job_inline(self, job: "_SessionJob", *,
                        capture_errors: bool = False) -> None:
        """Single-member group: plain sequential evaluation (the
        reference semantics) under the job's reserved run number. With
        ``capture_errors`` even non-transient failures land in
        ``job.exc`` — a single-job batch must isolate a poisoned
        request exactly like a merged group does."""
        self._tl.run_no = job.run_no
        self._tl.tag = job.tag
        try:
            job.out = self._execute_ops(job.config, job.docs, job.stats)
        except TransientLLMError as e:
            job.exc = e
        except Exception as e:  # noqa: BLE001 — re-raised by run_session
            if not capture_errors:
                raise
            job.exc = e
        finally:
            self._tl.tag = None

    def _run_group(self, group: List["_SessionJob"]) -> None:
        cond = threading.Condition()
        for job in group:
            job.cond = cond
        threads = [threading.Thread(target=self._job_main, args=(job,),
                                    name=f"repro-eval-{job.index}",
                                    daemon=True)
                   for job in group]
        for t in threads:
            t.start()
        try:
            with cond:
                while True:
                    live = [j for j in group if not j.done]
                    if not live:
                        break
                    if all(j.posted is not None for j in live):
                        stage = [j for j in live if j.posted is not None]
                        self._process_stage(stage)
                        for j in stage:
                            j.posted = None
                        cond.notify_all()
                    else:
                        cond.wait()
        except BaseException:
            # coordinator died: nobody will answer the barrier again —
            # mark the group aborted (parked jobs raise out of
            # rendezvous; jobs still computing fail at their next
            # dispatch) so no thread is left blocked forever, then
            # re-raise the coordinator's error
            with cond:
                for j in group:
                    j.aborted = True
                cond.notify_all()
            for t in threads:
                t.join()
            raise
        for t in threads:
            t.join()

    def _job_main(self, job: "_SessionJob") -> None:
        self._tl.run_no = job.run_no
        self._tl.channel = job
        try:
            job.out = self._execute_ops(job.config, job.docs, job.stats)
        except Exception as e:  # noqa: BLE001 — re-raised by run_session
            job.exc = e
        finally:
            self._tl.channel = None
            with job.cond:
                job.done = True
                job.cond.notify_all()

    def _submit_chunk(self, chunk: List["_StageEntry"]
                      ) -> Union[List[Any], TransientBackendError]:
        """One ``Backend.submit`` round-trip; a transient chunk-level
        failure is returned (not raised) so the coordinator can apply
        retry bookkeeping in canonical order."""
        try:
            return self.backend.submit([e.req for e in chunk])
        except TransientBackendError as e:
            return e

    def _process_stage(self, stage: List["_SessionJob"]) -> None:
        """Answer one merged stage: the posted request batches of every
        group member currently blocked in ``dispatch``.

        Canonical order is (job index, request index) — the order a
        sequential evaluation would have issued them. Cache lookups run
        first in that order; the remaining misses are grouped by cache
        key (identical in-flight requests across sibling candidates are
        answered by ONE backend call — the sequential run would have
        answered the duplicates from the cache) and submitted in
        ``preferred_batch_size`` chunks. Failure injection is evaluated
        only for each key group's leader, under the leader's job run
        number and per-entry attempt counter, so a job sees exactly the
        draws it would have seen sequentially; when a leader's job
        aborts, the next entry takes over with its own attempt counter
        from zero — again matching the sequential replay.
        """
        self.dispatch_stats["merged_stages"] += 1
        op_fps: Dict[int, str] = {}
        pending: List[_StageEntry] = []
        for job in stage:
            requests, _ = job.posted
            n = len(requests)
            self.dispatch_stats["merged_requests"] += n
            self._count_tag(job.tag, "requests", n)
            job.stage_results = [_UNSET] * n
            job.stage_usages = [None] * n
            job.stage_keys = [None] * n
            job.stage_error = None
            for li, req in enumerate(requests):
                if self._cacheable(req.kind):
                    key = self._cache_key(req, op_fps)
                    job.stage_keys[li] = key
                    hit = self.call_cache.lookup(key)
                    if hit is not None:
                        job.stage_results[li], job.stage_usages[li] = hit
                        continue
                pending.append(_StageEntry(job, li, req, job.stage_keys[li]))

        while pending:
            pending = [e for e in pending if e.job.stage_error is None]
            # group by key; keyless entries never share a backend call
            leaders: List[_StageEntry] = []
            groups: Dict[str, List[_StageEntry]] = {}
            for e in pending:
                if e.key is not None and e.key in groups:
                    groups[e.key].append(e)
                    continue
                if e.key is not None:
                    groups[e.key] = [e]
                leaders.append(e)
            next_pending: List[_StageEntry] = []
            live: List[_StageEntry] = []
            for e in leaders:
                if self._fails(e.req, e.attempt, e.job.run_no):
                    if e.attempt + 1 >= self.max_attempts:
                        e.job.stage_error = TransientLLMError(
                            f"simulated API failure in "
                            f"{e.req.op.get('name')} (gave up after "
                            f"{e.attempt + 1} attempts)")
                        # followers restart with their own attempt draws,
                        # as they would had the jobs run one by one
                        if e.key is not None:
                            next_pending.extend(groups[e.key][1:])
                        continue
                    e.attempt += 1
                    e.job.stats.retries += 1
                    next_pending.append(e)
                    if e.key is not None:
                        next_pending.extend(groups[e.key][1:])
                    continue
                live.append(e)
            chunks: List[List[_StageEntry]] = []
            for start in range(0, len(live), self.batch_hint):
                chunk = live[start:start + self.batch_hint]
                chunk = [e for e in chunk if e.job.stage_error is None]
                if chunk:
                    chunks.append(chunk)
            # pure backends (``concurrent_submit``) may answer the
            # stage's chunks in flight simultaneously — results are
            # still committed below in canonical chunk order, so
            # concurrency changes wall-clock only
            self.dispatch_stats["submit_calls"] += len(chunks)
            conc = min(self._session_concurrency, len(chunks))
            if conc > 1 and getattr(self.backend, "concurrent_submit",
                                    False):
                with ThreadPoolExecutor(max_workers=conc) as pool:
                    answers = list(pool.map(self._submit_chunk, chunks))
            else:
                answers = [self._submit_chunk(c) for c in chunks]
            for chunk, outs in zip(chunks, answers):
                if isinstance(outs, TransientBackendError):
                    for entry in chunk:
                        if entry.attempt + 1 >= self.max_attempts:
                            entry.job.stage_error = TransientLLMError(
                                f"backend failure persisted for "
                                f"{entry.attempt + 1} attempts: {outs}")
                            # followers belong to OTHER jobs: they retry
                            # with their own draws, as the sequential
                            # replay would after the leader's job died
                            if entry.key is not None:
                                next_pending.extend(groups[entry.key][1:])
                        else:
                            entry.attempt += 1
                            entry.job.stats.retries += 1
                            next_pending.append(entry)
                            if entry.key is not None:
                                next_pending.extend(groups[entry.key][1:])
                    continue
                if len(outs) != len(chunk):
                    raise RuntimeError(
                        f"{type(self.backend).__name__}.submit returned "
                        f"{len(outs)} results for {len(chunk)} requests")
                for entry, res in zip(chunk, outs):
                    if entry.job.stage_error is not None:
                        # the job died on an earlier chunk of this round:
                        # sequential dispatch would have raised before
                        # submitting this chunk, so its results must not
                        # enter the cache or reach followers — they
                        # re-issue for their own jobs instead
                        if entry.key is not None:
                            next_pending.extend(groups[entry.key][1:])
                        continue
                    if res.error is not None:
                        if isinstance(res.error, TransientBackendError):
                            if entry.attempt + 1 < self.max_attempts:
                                entry.attempt += 1
                                entry.job.stats.retries += 1
                                next_pending.append(entry)
                                if entry.key is not None:
                                    next_pending.extend(
                                        groups[entry.key][1:])
                                continue
                            entry.job.stage_error = TransientLLMError(
                                f"{entry.req.op.get('name')}: transient "
                                f"backend failure persisted for "
                                f"{entry.attempt + 1} attempts: "
                                f"{res.error}")
                            if entry.key is not None:
                                next_pending.extend(groups[entry.key][1:])
                            continue
                        entry.job.stage_error = res.error
                        # followers re-issue the request themselves (and
                        # will surface the same non-transient error for
                        # their own jobs, as sequential dispatch would)
                        if entry.key is not None:
                            next_pending.extend(groups[entry.key][1:])
                        continue
                    usage = res.usage if res.usage is not None else Usage()
                    if entry.key is not None:
                        self.call_cache.store(entry.key, res.value, usage,
                                              kind=entry.req.kind)
                        followers = groups[entry.key][1:]
                    else:
                        followers = []
                    for f in [entry] + followers:
                        # followers replay the stored record, exactly as
                        # their sequential cache hit would have
                        value = res.value if f is entry else \
                            copy.deepcopy(res.value)
                        f.job.stage_results[f.li] = value
                        f.job.stage_usages[f.li] = copy.deepcopy(usage) \
                            if f is not entry else usage
            pending = next_pending

        for job in stage:
            if job.stage_error is not None:
                job.reply_exc = job.stage_error
                continue
            requests, stats = job.posted
            assert not any(r is _UNSET for r in job.stage_results)
            for req, usage in zip(requests, job.stage_usages):
                stats.charge(req.op["name"], req.op.get("model", ""), usage,
                             self.backend)
            job.reply = job.stage_results
