"""Execution backends for semantic operators.

SimBackend
----------
A deterministic, seeded generative model of LLM behaviour over synthetic
documents, calibrated to the phenomena the paper's optimizer exploits.
Documents carry hidden *facts* — (tag, value) pairs embedded as sentences
whose surface form either contains the tag's canonical keyword or a
paraphrase (keyword absent). The backend simulates an LLM reading the
document's *current text* (so upstream compression/chunking genuinely
gates what downstream operators can find):

- recall of a fact = model capability x task-complexity factor (number of
  task_tags the prompt asks for at once) x context-length factor (decays
  toward the model's MRCR-style long-context score; text beyond the
  context window is truncated) x per-(model,tag) seeded noise;
- paraphrased facts are only found by LLMs (scaled by capability); code
  ops (regex/keyword, codeops.py) match canonical keywords exactly —
  cheap, precise, bounded recall;
- prompt-engineering features (clarified / few-shot, set by directives)
  give bounded boosts that are larger for weaker models (paper §B.5.2);
- per-(model, domain) specialization jitter makes the best model
  workload-dependent (paper Table 6);
- costs follow the paper's cost model: tokens x per-token price of the
  model, prices derived from the roofline analysis (models_catalog).

Determinism: every stochastic decision hashes (seed, doc id, op fields,
model, tag) — identical pipelines on identical data give identical
results, which the executor's cache relies on (paper §4.3.3).

JaxBackend
----------
Operators execute real forward passes of reduced-config JAX models from
the pool (real tokenization, prefill/decode, token counting). Used by
integration tests and the serving example — it validates the substrate,
not extraction quality (models are untrained).

Both backends implement the batched Backend protocol v2
(``submit(list[OpRequest]) -> list[OpResult]``): SimBackend as a
vectorized per-request sweep (a pure function gains nothing from
batching but must answer the batched surface), JaxBackend by routing
generation chunks through the continuous-batching scheduler. The legacy
per-document ``run_*`` methods remain as the kind-specific
implementations and keep v1 compatibility via ``LegacyBackendAdapter``.
"""

from __future__ import annotations

import hashlib
import re
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from repro.core.models_catalog import ModelCard, catalog
from repro.data.documents import (Dataset, Document, doc_text,
                                  main_text_key, word_count)
from repro.engine.codeops import sentences
from repro.pipeline.protocols import OpRequest, OpResult, execute_request

WORDS_PER_TOKEN = 0.75


@dataclass
class Usage:
    in_tokens: int = 0
    out_tokens: int = 0
    calls: int = 0

    def add(self, other: "Usage"):
        self.in_tokens += other.in_tokens
        self.out_tokens += other.out_tokens
        self.calls += other.calls


def tokens_of(text: str) -> int:
    return int(word_count(text) / WORDS_PER_TOKEN) + 1


# hidden per-model text-task capability (the optimizer never sees these;
# it only observes measured accuracy/cost)
_CAPABILITY = {
    "grok-1-314b": 0.95,
    "gemma3-27b": 0.92,
    "granite-34b": 0.90,
    "gemma2-9b": 0.88,
    "zamba2-2.7b": 0.78,
    "llama3.2-1b": 0.74,
    "granite-moe-1b-a400m": 0.70,
    "internvl2-1b": 0.66,
    "mamba2-370m": 0.60,
    "whisper-medium": 0.50,
}


def _hash01(*parts) -> float:
    h = hashlib.blake2s("|".join(str(p) for p in parts).encode()).digest()
    return int.from_bytes(h[:8], "little") / 2**64


def default_equijoin(op: Dict[str, Any], doc: Document
                     ) -> Tuple[Optional[Dict], Usage]:
    """Semantic join of one document against ``op['right_docs']``: the
    shared implementation both backends (and the LegacyBackendAdapter
    fallback) use. Returns (``right_*``-prefixed fields of the best
    match, or None) plus the per-probe usage."""
    right = op.get("right_docs", [])
    lval = str(doc.get(op["left_field"], "")).lower()
    fld_r = op["right_field"]
    best = None
    for r in right:
        if str(r.get(fld_r, "")).lower() == lval:
            best = r
            break
    usage = Usage(in_tokens=40 * max(len(right), 1), out_tokens=4, calls=1)
    if best is None:
        return None, usage
    return {f"right_{k}": v for k, v in best.items()
            if not k.startswith("_")}, usage


class SimBackend:
    # Backend-protocol batching hint: the simulator is a pure function of
    # (seed, doc, op), so any chunking yields identical results — but
    # cross-pipeline dispatch sessions merge sibling candidates' request
    # streams, and a real batched endpoint amortizes per-call overhead
    # across the chunk. Advertise a real batch so merged (mixed-pipeline,
    # mixed-op) stages ride fewer ``submit`` round-trips.
    preferred_batch_size = 16
    # results depend only on (seed, domain, op, doc): the executor's
    # content-addressed call cache may memoize invocations
    deterministic = True
    # ...and submit holds no mutable state, so a dispatch session may
    # keep several chunks of a merged stage in flight at once
    concurrent_submit = True

    def __init__(self, seed: int = 0, domain: str = "generic",
                 cards: Optional[Dict[str, ModelCard]] = None):
        self.seed = seed
        self.domain = domain
        self.cards = cards or catalog()

    def fingerprint(self) -> Tuple[Any, ...]:
        # custom card sets change context windows and therefore results:
        # key them by content (prices + windows), not object identity
        from repro.data.documents import content_hash
        cards_fp = None if self.cards is catalog() else content_hash(
            sorted((name, str(card)) for name, card in self.cards.items()))
        return ("sim", self.seed, self.domain, cards_fp)

    # -- batched dispatch (Backend protocol v2) -------------------------------

    def submit(self, requests: List[OpRequest]) -> List[OpResult]:
        """Vectorized entry point: the simulator is a pure per-request
        function, so the batch executes as a straight sweep (via the
        shared kind -> ``run_*`` routing) — no cross-request state, any
        chunking yields identical results."""
        out = []
        for req in requests:
            value, usage = execute_request(self, req)
            out.append(OpResult(value=value, usage=usage))
        return out

    # -- internals ----------------------------------------------------------

    def _card(self, model: str) -> ModelCard:
        return self.cards[model]

    def _quality(self, model: str, op: Dict[str, Any]) -> float:
        base = _CAPABILITY[model]
        # per-(model, domain) specialization: +-0.06
        jitter = (_hash01(self.seed, "spec", model, self.domain) - 0.5) * 0.12
        q = base + jitter
        feats = op.get("prompt_features", {})
        weak = 1.0 - base
        boost = 0.0
        if feats.get("clarified"):
            boost += min(0.08, 0.03 + 0.10 * weak) * min(feats["clarified"], 2)
        if feats.get("few_shot"):
            boost += min(0.06, 0.02 + 0.08 * weak)
        if feats.get("gleaning"):
            # validator-feedback rounds (DocETL-V1 gleaning)
            boost += 0.04 * min(feats["gleaning"], 2)
        # prompt tricks interact SUB-additively: stacking clarify + few-shot
        # + gleaning on one operator saturates (real LLMs don't compound
        # prompt hacks linearly) — greedy single-op stacking plateaus, and
        # structural rewrites (what MOAR searches) stay the bigger lever
        q += min(boost, 0.055 + 0.07 * weak)
        return min(q, 0.99)

    def _complexity_factor(self, op: Dict[str, Any], n_words: int) -> float:
        """Task difficulty: how many task units the prompt asks for at
        once (task_tags), floored by the task's intrinsic breadth (e.g.
        biodex's 24k-label space -> task_breadth). Effective breadth
        scales with the visible context: the same question over a 300-word
        chunk is easier than over the full document — this is what makes
        the paper's data-decomposition rewrites pay off."""
        n = max(len(op.get("task_tags", [])), op.get("task_breadth", 1))
        scale = min(1.0, (max(n_words, 50) / 2000.0) ** 0.5)
        n_eff = 1.0 + (n - 1) * scale
        return 0.975 ** max(n_eff - 1.0, 0.0)

    def _context_factor(self, model: str, n_words: int) -> Tuple[float, int]:
        """Returns (quality multiplier, visible words)."""
        card = self._card(model)
        window_words = int(card.context_window * WORDS_PER_TOKEN)
        visible = min(n_words, window_words)
        frac = visible / max(window_words, 1)
        if frac <= 0.25:
            f = 1.0
        else:
            # linear decay from 1.0 at 25% toward long_context_score at 100%
            f = 1.0 - (frac - 0.25) / 0.75 * (1.0 - card.long_context_score)
        return f, visible

    def _present_facts(self, doc: Document) -> List[Dict[str, Any]]:
        """Facts whose evidence sentence survives in the current text."""
        text = doc_text(doc)
        out = []
        for f in doc.get("_facts", []):
            idx = text.find(f["value"])
            if idx >= 0:
                pos_words = word_count(text[:idx])
                out.append({**f, "pos_words": pos_words})
        return out

    def _usage(self, op, in_text_tokens: int, out_tokens: int) -> Usage:
        prompt_toks = tokens_of(op.get("prompt", "")) + 30
        feats = op.get("prompt_features", {})
        if feats.get("few_shot"):
            prompt_toks += 120 * min(feats["few_shot"], 4)
        mult = 1.0 + 0.6 * min(feats.get("gleaning", 0), 3)
        if op.get("lean_output"):
            out_tokens = max(4, int(out_tokens * 0.6))
        return Usage(in_tokens=int((prompt_toks + in_text_tokens) * mult),
                     out_tokens=int(out_tokens * mult),
                     calls=1 + min(feats.get("gleaning", 0), 3))

    def usage_cost(self, model: str, usage: Usage) -> float:
        card = self._card(model)
        return (usage.in_tokens * card.price_in
                + usage.out_tokens * card.price_out) / 1e6

    # -- semantic operator implementations -----------------------------------

    def run_map(self, op: Dict[str, Any], doc: Document) -> Tuple[Dict, Usage]:
        model = op["model"]
        if op.get("format_field"):
            # formatting/narrative map over pre-aggregated items (the LLM
            # half of a code_reduce split): cheap, high fidelity
            items = doc.get(op["format_field"]) or []
            q = self._quality(model, op)
            kept = [i for i in items
                    if _hash01(self.seed, "fmt", model, str(i)) < min(0.995, q + 0.15)]
            schema = op.get("output_schema", {})
            out_field = next(iter(schema), "formatted")
            usage = self._usage(op, 12 * max(len(items), 1),
                                10 * max(len(kept), 1))
            return {out_field: kept}, usage
        tags = op.get("task_tags", [])
        text = doc_text(doc)
        nw = word_count(text)
        q = self._quality(model, op)
        cf, visible = self._context_factor(model, nw)
        comp = self._complexity_factor(op, nw)
        present = self._present_facts(doc)

        found = []
        for f in present:
            if f["tag"] not in tags:
                continue
            if f["pos_words"] > visible:   # truncated out of the window
                continue
            p = q * comp * cf
            if f.get("paraphrased"):
                p *= 0.55 + 0.45 * q       # paraphrase: capability-gated
            r = _hash01(self.seed, "map", doc.get("id"), model, f["tag"],
                        f["value"], op.get("prompt_features", {}),
                        len(tags) // 8)
            if r < p:
                found.append(f)
        # hallucinations: rate grows with task breadth, shrinks with quality
        halls = []
        fp_rate = 0.015 * (1.0 - q) * (1 + len(tags) / 16)
        for tag in tags:
            r = _hash01(self.seed, "fp", doc.get("id"), model, tag)
            if r < fp_rate:
                halls.append({"tag": tag, "value": f"spurious_{tag[:12]}"})

        schema = op.get("output_schema", {})
        out_field = next(iter(schema), "extractions")
        include_evidence = op.get("include_evidence", True)
        items = []
        for f in found:
            item = {"tag": f["tag"], "value": f["value"]}
            if include_evidence:
                item["evidence"] = f"...{f['value']}..."
            items.append(item)
        items += [{"tag": h["tag"], "value": h["value"]} for h in halls]
        out_tokens = 8 + 18 * len(items)
        fields = {out_field: items}
        flag_spec = op.get("emit_filter_flag")
        if flag_spec:
            # fused map+filter: the map also evaluates the filter predicate
            # (a joint task — slightly harder than a dedicated filter call)
            ftag = flag_spec.get("tag", "")
            if ftag:
                truth = any(f["tag"] == ftag for f in present)
            else:
                truth = bool(doc.get(flag_spec.get("truth_field", "_keep"),
                                     True))
            r = _hash01(self.seed, "fusedflt", doc.get("id"), model, ftag,
                        flag_spec.get("truth_field", ""))
            correct = r < q * cf * 0.98
            fields[flag_spec["field"]] = truth if correct else not truth
            out_tokens += 4
        return fields, self._usage(
            op, int(min(nw, visible) / WORDS_PER_TOKEN), out_tokens)

    def run_classify(self, op: Dict[str, Any], doc: Document,
                     classes: List[str], truth_field: str
                     ) -> Tuple[str, Usage]:
        """map specialization: single-label classification."""
        model = op["model"]
        text = doc_text(doc)
        q = self._quality(model, op)
        cf, visible = self._context_factor(model, word_count(text))
        comp = self._complexity_factor(
            {"task_breadth": max(len(classes) // 4, 1)}, word_count(text))
        truth = doc.get(truth_field, classes[0])
        r = _hash01(self.seed, "cls", doc.get("id"), model, truth_field,
                    op.get("prompt_features", {}))
        if r < q * cf * comp:
            label = truth
        else:
            idx = int(_hash01(self.seed, "clswrong", doc.get("id"), model)
                      * len(classes))
            label = classes[min(idx, len(classes) - 1)]
        return label, self._usage(op, int(visible / WORDS_PER_TOKEN), 12)

    def run_filter(self, op: Dict[str, Any], doc: Document
                   ) -> Tuple[bool, Usage]:
        model = op["model"]
        tag = op.get("filter_tag", "")
        text = doc_text(doc)
        q = self._quality(model, op)
        cf, visible = self._context_factor(model, word_count(text))
        if tag:
            truth = any(f["tag"] == tag for f in self._present_facts(doc))
        else:
            truth = bool(doc.get(op.get("filter_truth_field", "_keep"), True))
        r = _hash01(self.seed, "flt", doc.get("id"), model, tag,
                    op.get("prompt_features", {}))
        correct = r < q * cf
        keep = truth if correct else not truth
        if op.get("bias_recall") and truth:
            # recall-biased pre-filter (cascade stage): never drops a true
            # positive; precision errors remain
            keep = True
        return keep, \
            self._usage(op, int(visible / WORDS_PER_TOKEN), 4)

    def run_reduce(self, op: Dict[str, Any], docs: Dataset
                   ) -> Tuple[Dict, Usage]:
        """Aggregates either pre-extracted fields (cheap, accurate) or raw
        text (the whole group's text becomes the context — expensive and
        context-limited, the BlackVault failure mode)."""
        model = op["model"]
        q = self._quality(model, op)
        agg_field = op.get("aggregate_field")
        usage = Usage()
        items: List[Any] = []
        if agg_field and any(agg_field in d for d in docs):
            # combine pre-extracted lists; upstream evidence improves dedup
            has_evidence = any(
                isinstance(v, list) and v and isinstance(v[0], dict)
                and "evidence" in v[0]
                for v in (d.get(agg_field) for d in docs) if v)
            dedup_q = min(0.98, q + (0.10 if has_evidence else 0.0))
            # combining is easier than extraction but not free: each unique
            # item survives the merge with quality-dependent probability —
            # a weak merge model quietly drops findings, so the chunk-merge
            # model choice interacts with upstream rewrites (paper §1)
            keep_q = min(0.995, q + 0.12)
            seen = set()
            for d in docs:
                vals = d.get(agg_field) or []
                vals = vals if isinstance(vals, list) else [vals]
                for v in vals:
                    key = str(v.get("value", v) if isinstance(v, dict) else v)
                    r = _hash01(self.seed, "dedup", model, key)
                    if key in seen and r < dedup_q:
                        continue  # correctly deduplicated
                    if key not in seen:
                        seen.add(key)
                        if _hash01(self.seed, "mergekeep", model, key) < keep_q:
                            items.append(v)
            in_toks = sum(tokens_of(str(d.get(agg_field, ""))) for d in docs)
            usage.add(self._usage(op, in_toks, 12 * max(len(items), 1)))
        else:
            # re-analyze raw text of the whole group in one call
            joined = " ".join(doc_text(d) for d in docs)
            tags = op.get("task_tags", [])
            nw_joined = word_count(joined)
            cf, visible = self._context_factor(model, nw_joined)
            comp = self._complexity_factor(op, nw_joined)
            budget_words = 0
            for d in docs:
                present = self._present_facts(d)
                t = doc_text(d)
                offset = budget_words
                budget_words += word_count(t)
                for f in present:
                    if not tags or f["tag"] in tags:
                        if offset + f["pos_words"] > visible:
                            continue
                        p = q * comp * cf
                        if f.get("paraphrased"):
                            p *= 0.55 + 0.45 * q
                        r = _hash01(self.seed, "redraw", model, f["value"])
                        if r < p:
                            items.append({"tag": f["tag"], "value": f["value"]})
            usage.add(self._usage(op, int(visible / WORDS_PER_TOKEN),
                                  12 * max(len(items), 1)))
        schema = op.get("output_schema", {})
        out_field = next(iter(schema), "aggregated")
        return {out_field: items}, usage

    def run_summarize(self, op: Dict[str, Any], doc: Document
                      ) -> Tuple[Dict, Usage]:
        """LLM document summarization (projection synthesis): output is a
        REWRITE — recalled facts are re-stated in canonical form (an LLM
        normalizes paraphrases), noise is dropped. Downstream code ops can
        therefore match facts that were paraphrased in the original."""
        model = op["model"]
        text = doc_text(doc)
        q = self._quality(model, op)
        cf, visible = self._context_factor(model, word_count(text))
        kept = []
        for f in self._present_facts(doc):
            if f["pos_words"] > visible:
                continue
            p = min(0.98, q * cf + 0.03)
            if f.get("paraphrased"):
                p *= 0.65 + 0.35 * q
            if _hash01(self.seed, "summ", doc.get("id"), model,
                       f["value"]) < p:
                kept.append(f)
        key = main_text_key(doc)
        lines = [f"summary of the source document ({len(kept)} findings)."]
        for f in kept:
            lines.append(
                f"the record notes a [{f['tag']}] matter involving "
                f"{f['value']}.")
        summary = " ".join(lines)
        usage = self._usage(op, int(visible / WORDS_PER_TOKEN),
                            tokens_of(summary))
        return {key: summary}, usage

    def run_extract(self, op: Dict[str, Any], doc: Document
                    ) -> Tuple[Dict, Usage]:
        """LLM-based document compression: returns line ranges -> text
        subset. Finds fact sentences incl. paraphrases (capability-gated);
        output tokens are just the ranges (cheap)."""
        model = op["model"]
        tags = op.get("task_tags", [])
        text = doc_text(doc)
        q = self._quality(model, op)
        cf, visible = self._context_factor(model, word_count(text))
        kept_values = []
        for f in self._present_facts(doc):
            if tags and f["tag"] not in tags:
                continue
            if f["pos_words"] > visible:
                continue
            p = min(0.98, (q * cf) + 0.05)  # extraction is easier than QA
            if f.get("paraphrased"):
                p *= 0.6 + 0.4 * q
            if _hash01(self.seed, "ext", doc.get("id"), model,
                       f["value"]) < p:
                kept_values.append(f["value"])
        sents = sentences(text)
        kept = [s for s in sents if any(v in s for v in kept_values)]
        # keep ~10% neutral context lines
        kept += [s for i, s in enumerate(sents)
                 if _hash01(self.seed, "extn", doc.get("id"), i) < 0.10]
        # explicit text_key override wins; default to the main text field
        key = op.get("text_key") or main_text_key(doc)
        usage = self._usage(op, int(visible / WORDS_PER_TOKEN), 30)
        return {key: " ".join(dict.fromkeys(kept))}, usage

    def run_equijoin(self, op: Dict[str, Any], doc: Document
                     ) -> Tuple[Optional[Dict], Usage]:
        """Semantic join probe: exact-match against op['right_docs']."""
        return default_equijoin(op, doc)

    def run_resolve(self, op: Dict[str, Any], docs: Dataset
                    ) -> Tuple[Dataset, Usage]:
        """Canonicalize near-duplicate values of a field across docs."""
        model = op["model"]
        fld = op.get("resolve_field", "")
        q = self._quality(model, op)
        usage = Usage()
        canon: Dict[str, str] = {}
        out = []
        for d in docs:
            v = str(d.get(fld, ""))
            base = re.sub(r"[^a-z0-9]", "", v.lower())
            r = _hash01(self.seed, "res", model, base)
            key = base if r < q else v
            canon.setdefault(key, v)
            nd = dict(d)
            nd[fld] = canon[key]
            out.append(nd)
            usage.add(Usage(in_tokens=tokens_of(v) + 20, out_tokens=8, calls=1))
        return out, usage


class JaxBackend:
    """Operators run real reduced-model forward passes from the pool.

    ``submit`` batches generation: requests are grouped by model and run
    through the fixed-slot continuous batcher (``serving/scheduler.py``),
    so prefill/decode of a chunk genuinely amortizes — one jitted decode
    step serves every active slot. Encoder-decoder and VLM architectures
    need extra prefill inputs the scheduler doesn't thread, so they fall
    back to per-request decoding.
    """

    # Backend-protocol batching hint: real decoding amortizes prefill
    # across requests. Chunks may exceed the decode slot count — the
    # continuous batcher queues the overflow and admits as slots retire,
    # so merged mixed-pipeline stages from a dispatch session still
    # drain in one ``run_until_drained`` sweep per model.
    preferred_batch_size = 8
    # fixed decode-batch width of the continuous batcher (default; the
    # constructor's ``decode_slots`` overrides per instance — serving
    # hosts size it to their traffic via ``--slots``)
    DECODE_SLOTS = 4
    # NOT memoizable: the fixed-slot batcher pads every slot to the max
    # active length, so a request's decoded tokens depend on which other
    # requests share its chunk — caching would freeze one batch
    # composition's answer and make search order-dependent
    deterministic = False

    # prompt truncation: the serving path tokenizes at most this many ids
    MAX_PROMPT_TOKENS = 96

    def __init__(self, seed: int = 0, max_new_tokens: int = 8,
                 decode_slots: Optional[int] = None,
                 clock: Optional[Any] = None,
                 strict_compile: bool = False):
        import time

        import jax
        from repro.configs import get_config
        from repro.models import api
        self._api = api
        self._get_config = get_config
        self._jax = jax
        self.seed = seed
        self.max_new_tokens = max_new_tokens
        # compile-path static-analysis gate (repro.analysis.compiled):
        # every model is audited once at load. False (default) runs the
        # fast jaxpr tier and surfaces findings as warnings; True also
        # compiles the decode step and raises on any error diagnostic.
        self.strict_compile = strict_compile
        if decode_slots is not None:
            self.DECODE_SLOTS = max(1, int(decode_slots))
        # threaded into each ContinuousBatcher so request timestamps can
        # participate in a host's (possibly virtual) timeline; accepts a
        # bare callable or a serving-layer clock object (.now())
        if clock is None:
            self.clock = time.time
        elif callable(getattr(clock, "now", None)):
            self.clock = clock.now
        else:
            self.clock = clock
        self._params = {}
        self._batchers: Dict[str, Any] = {}
        self.cards = catalog()

    def fingerprint(self) -> Tuple[Any, ...]:
        return ("jax", self.seed, self.max_new_tokens, self.DECODE_SLOTS)

    def close(self) -> None:
        """Backend lifecycle hook (``backend_close``): drop the model
        params and per-model batchers so device buffers are reclaimable
        once a serving host shuts down."""
        self._batchers.clear()
        self._params.clear()

    def _model(self, name: str):
        if name not in self._params:
            self._audit_compile(name)
            cfg = self._get_config(name, reduced=True)
            params = self._api.init_params(
                self._jax.random.PRNGKey(self.seed), cfg)
            self._params[name] = (cfg, params)
        return self._params[name]

    # process-wide audit memo: the lint is a pure function of the arch's
    # (frozen) config, so one report serves every backend instance
    _audit_cache: Dict[Tuple[str, bool], Any] = {}

    def _audit_compile(self, name: str) -> None:
        """Construction-time compile-path lint gate: warn by default,
        raise under ``strict_compile`` (errors always fatal there; the
        jaxpr tier alone is milliseconds, so the default path stays
        cheap — the HLO tier only runs when strict)."""
        import warnings

        from repro.analysis.compiled import audit_model
        key = (name, self.strict_compile)
        report = self._audit_cache.get(key)
        if report is None:
            report = audit_model(name, compile=self.strict_compile)
            self._audit_cache[key] = report
        if self.strict_compile:
            report.raise_for_errors()
        for d in report.diagnostics:
            warnings.warn(f"compile-lint: {d.format()}", stacklevel=3)

    # -- batched dispatch (Backend protocol v2) -------------------------------

    def submit(self, requests: List[OpRequest]) -> List[OpResult]:
        results: List[Optional[OpResult]] = [None] * len(requests)
        by_model: Dict[str, List[int]] = {}
        for i, req in enumerate(requests):
            if req.kind == "resolve":
                results[i] = OpResult(value=list(req.docs), usage=Usage())
            elif req.kind == "equijoin":
                value, usage = default_equijoin(req.op, req.doc)
                results[i] = OpResult(value=value, usage=usage)
            else:
                by_model.setdefault(req.op["model"], []).append(i)
        for model, idxs in by_model.items():
            prompts = [self._prompt_for(requests[i]) for i in idxs]
            for i, (toks, usage) in zip(idxs,
                                        self._generate_batch(model, prompts)):
                results[i] = OpResult(
                    value=self._value_for(requests[i], toks), usage=usage)
        return results

    def _prompt_for(self, req: OpRequest) -> str:
        op = req.op
        if req.kind in ("map", "summarize", "filter"):
            return f"{op.get('prompt', '')}\n{doc_text(req.doc)[:2000]}"
        if req.kind == "extract":
            return doc_text(req.doc)[:2000]
        if req.kind == "classify":
            return doc_text(req.doc)[:1000]
        if req.kind == "reduce":
            joined = " ".join(doc_text(d)[:400] for d in req.docs[:8])
            return f"{op.get('prompt', '')}\n{joined}"
        raise TypeError(f"JaxBackend cannot execute request kind "
                        f"{req.kind!r}")

    def _value_for(self, req: OpRequest, toks: List[int]) -> Any:
        op = req.op
        if req.kind in ("map", "summarize"):
            out_field = next(iter(op.get("output_schema", {})), "output")
            return {out_field: [{"tag": "gen",
                                 "value": " ".join(map(str, toks))}]}
        if req.kind == "filter":
            return bool(toks[0] % 2)
        if req.kind == "extract":
            key = op.get("text_key") or main_text_key(req.doc)
            words = doc_text(req.doc).split()
            return {key: " ".join(words[:len(words) // 2])}
        if req.kind == "classify":
            classes = req.extra["classes"]
            return classes[toks[0] % len(classes)]
        out_field = next(iter(op.get("output_schema", {})), "aggregated")
        return {out_field: [{"tag": "gen", "value": str(t)} for t in toks]}

    def _batcher(self, model: str):
        """Persistent per-model continuous batcher: the jitted decode
        step compiles once and is reused across submit calls
        (``run_until_drained`` drains per call, so batches don't mix)."""
        b = self._batchers.get(model)
        if b is None:
            from repro.serving.scheduler import ContinuousBatcher
            cfg, params = self._model(model)
            b = ContinuousBatcher(
                params, cfg, num_slots=self.DECODE_SLOTS,
                max_len=self.MAX_PROMPT_TOKENS + self.max_new_tokens + 8,
                eos_id=-1,  # match generate(): no early EOS stop
                clock=self.clock)
            self._batchers[model] = b
        return b

    def _generate_batch(self, model: str, texts: List[str]
                        ) -> List[Tuple[List[int], Usage]]:
        import numpy as np
        from repro.data.tokenizer import HashWordTokenizer
        cfg, params = self._model(model)
        if cfg.is_encoder_decoder or cfg.family == "vlm":
            # extra prefill inputs (frames / patch embeds) aren't threaded
            # through the scheduler — decode these per request
            return [self._generate(model, t) for t in texts]
        tok = HashWordTokenizer(cfg.vocab_size)
        batcher = self._batcher(model)
        ids_list = [tok.encode(t)[:self.MAX_PROMPT_TOKENS] for t in texts]
        uids = [batcher.submit(np.asarray(ids, np.int32),
                               max_new_tokens=self.max_new_tokens)
                for ids in ids_list]
        finished = {r.uid: r for r in batcher.run_until_drained()}
        out = []
        for uid, ids in zip(uids, ids_list):
            usage = Usage(in_tokens=len(ids),
                          out_tokens=self.max_new_tokens, calls=1)
            out.append((list(finished[uid].generated), usage))
        return out

    def _generate(self, model: str, text: str) -> Tuple[List[int], Usage]:
        import numpy as np
        from repro.data.tokenizer import HashWordTokenizer
        from repro.serving.decode import generate
        cfg, params = self._model(model)
        tok = HashWordTokenizer(cfg.vocab_size)
        ids = tok.encode(text)[:self.MAX_PROMPT_TOKENS]
        prompt = np.asarray(ids, dtype=np.int32)[None, :]
        extra = {}
        if cfg.is_encoder_decoder:
            rng = np.random.default_rng(self.seed)
            extra["frames"] = rng.standard_normal(
                (1, cfg.encoder_seq_len, cfg.d_model)).astype("float32") * 0.1
        if cfg.family == "vlm":
            rng = np.random.default_rng(self.seed)
            vd = cfg.vit_dim or cfg.d_model
            extra["patch_embeds"] = rng.standard_normal(
                (1, cfg.num_patches, vd)).astype("float32") * 0.1
        out = generate(params, cfg, self._jax.numpy.asarray(prompt),
                       self.max_new_tokens, extra_inputs=extra or None)
        usage = Usage(in_tokens=len(ids), out_tokens=self.max_new_tokens,
                      calls=1)
        return list(out[0]), usage

    def usage_cost(self, model: str, usage: Usage) -> float:
        card = self.cards[model]
        return (usage.in_tokens * card.price_in
                + usage.out_tokens * card.price_out) / 1e6

    def _run_one(self, req: OpRequest) -> Tuple[Any, Usage]:
        """v1 per-request path: same prompt construction and output
        shaping as the batched path, minus the scheduler."""
        toks, usage = self._generate(req.op["model"], self._prompt_for(req))
        return self._value_for(req, toks), usage

    def run_map(self, op, doc):
        return self._run_one(OpRequest("map", op, doc=doc))

    def run_filter(self, op, doc):
        return self._run_one(OpRequest("filter", op, doc=doc))

    def run_reduce(self, op, docs):
        return self._run_one(OpRequest("reduce", op, docs=list(docs)))

    def run_extract(self, op, doc):
        return self._run_one(OpRequest("extract", op, doc=doc))

    def run_classify(self, op, doc, classes, truth_field):
        return self._run_one(OpRequest(
            "classify", op, doc=doc,
            extra={"classes": classes, "truth_field": truth_field}))

    def run_equijoin(self, op, doc):
        return default_equijoin(op, doc)

    def run_resolve(self, op, docs):
        usage = Usage()
        return list(docs), usage
