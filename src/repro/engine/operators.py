"""Operator and pipeline configuration helpers (paper §2.1, Table 7).

Compatibility layer over the typed public API in :mod:`repro.pipeline`.
Operators remain JSON-serializable dicts (DocETL specifies pipelines in
YAML; the dict-of-parameters shape keeps rewrites pure config
transformations and pipelines hashable for caching), but the *vocabulary*
now lives in the ``repro.pipeline`` operator registry: validation rules,
execution, cost semantics, and rewrite-target metadata are bundled per
type, and the historical ``SEMANTIC_TYPES``/``AUX_TYPES``/``CODE_TYPES``
constants are live views over the registry — an operator type registered
at runtime is immediately a member.

Required keys per operator: ``name``, ``type``. Semantic operators carry
``prompt`` (natural-language spec), ``output_schema`` (field -> type str),
``model``; code-powered operators carry ``code`` (a CodeSpec, see
codeops.py). Type-specific rules live on each ``OperatorSpec``
(engine/builtin_ops.py for the Table 7 set).

Semantic op prompts also carry ``task_tags``: the workload-level task
units the prompt asks for (e.g. clause types). These mirror how DocETL
prompts enumerate categories, and the SimBackend grounds its behaviour in
them (an op asking for 41 tags at once is "harder" than one asking for 3).
"""

from __future__ import annotations

import copy
from typing import List

from repro.data.documents import content_hash
from repro.engine import builtin_ops  # noqa: F401 — registers Table 7 ops
from repro.pipeline.model import Op, Pipeline, as_config  # noqa: F401
from repro.pipeline.spec import (KIND_AUX, KIND_CODE, KIND_LLM, OpConfig,
                                 PipelineConfig, TypeView, is_llm_type,
                                 validate_op, validate_pipeline_config)
# compatibility re-exports: the registry surface moved to
# repro.pipeline.spec in PR 1; old import sites keep working
from repro.pipeline.spec import (PipelineValidationError,  # noqa: F401
                                 operator_spec)  # noqa: F401

# live registry views: custom registrations are immediately members
SEMANTIC_TYPES = TypeView(KIND_LLM)
AUX_TYPES = TypeView(KIND_AUX)
CODE_TYPES = TypeView(KIND_CODE)
ALL_TYPES = TypeView()

# operator types that invoke an LLM
LLM_TYPES = SEMANTIC_TYPES


def make_pipeline(name: str, operators: List[OpConfig]) -> PipelineConfig:
    return {"name": name, "operators": operators}


def pipeline_hash(pipeline) -> str:
    if isinstance(pipeline, Pipeline):
        return pipeline.hash
    return content_hash(pipeline["operators"])


def clone_pipeline(pipeline: PipelineConfig) -> PipelineConfig:
    return copy.deepcopy(as_config(pipeline))


def op_types(pipeline) -> List[str]:
    return [op["type"] for op in as_config(pipeline)["operators"]]


def models_used(pipeline) -> List[str]:
    return [op.get("model", "") for op in as_config(pipeline)["operators"]
            if is_llm_type(op["type"])]


def validate_operator(op: OpConfig) -> None:
    validate_op(op)


def validate_pipeline(pipeline) -> None:
    validate_pipeline_config(as_config(pipeline))


def output_fields(pipeline) -> set:
    out: set = set()
    for op in as_config(pipeline)["operators"]:
        out |= set((op.get("output_schema") or {}).keys())
    return out


def count_llm_ops(pipeline) -> int:
    return sum(1 for op in as_config(pipeline)["operators"]
               if is_llm_type(op["type"]))


def describe(pipeline) -> str:
    parts = []
    for op in as_config(pipeline)["operators"]:
        model = op.get("model", "")
        parts.append(f"{op['type']}({op['name']}{',' + model if model else ''})")
    return " -> ".join(parts)
