"""Operator and pipeline configuration model (paper §2.1, Table 7).

Operators are JSON-serializable dicts (DocETL specifies pipelines in YAML;
we keep the same dict-of-parameters shape so rewrites are pure config
transformations and pipelines hash for caching).

Required keys per operator: ``name``, ``type``. Semantic operators carry
``prompt`` (natural-language spec), ``output_schema`` (field -> type str),
``model``; code-powered operators carry ``code`` (a CodeSpec, see
codeops.py). Type-specific keys documented per validator below.

Semantic op prompts also carry ``task_tags``: the workload-level task
units the prompt asks for (e.g. clause types). These mirror how DocETL
prompts enumerate categories, and the SimBackend grounds its behaviour in
them (an op asking for 41 tags at once is "harder" than one asking for 3).
"""

from __future__ import annotations

import copy
from typing import Any, Dict, List, Optional

from repro.data.documents import content_hash

OpConfig = Dict[str, Any]
PipelineConfig = Dict[str, Any]

SEMANTIC_TYPES = {"map", "parallel_map", "reduce", "filter", "resolve",
                  "equijoin", "extract"}
AUX_TYPES = {"unnest", "split", "gather", "sample"}
CODE_TYPES = {"code_map", "code_reduce", "code_filter"}
ALL_TYPES = SEMANTIC_TYPES | AUX_TYPES | CODE_TYPES

# operator types that invoke an LLM
LLM_TYPES = SEMANTIC_TYPES


class PipelineValidationError(ValueError):
    pass


def make_pipeline(name: str, operators: List[OpConfig]) -> PipelineConfig:
    return {"name": name, "operators": operators}


def pipeline_hash(pipeline: PipelineConfig) -> str:
    return content_hash(pipeline["operators"])


def clone_pipeline(pipeline: PipelineConfig) -> PipelineConfig:
    return copy.deepcopy(pipeline)


def op_types(pipeline: PipelineConfig) -> List[str]:
    return [op["type"] for op in pipeline["operators"]]


def models_used(pipeline: PipelineConfig) -> List[str]:
    return [op.get("model", "") for op in pipeline["operators"]
            if op["type"] in LLM_TYPES]


def validate_operator(op: OpConfig) -> None:
    if "name" not in op or "type" not in op:
        raise PipelineValidationError(f"operator missing name/type: {op}")
    t = op["type"]
    if t not in ALL_TYPES:
        raise PipelineValidationError(f"unknown operator type {t!r}")
    if t in SEMANTIC_TYPES and t != "extract":
        if not op.get("prompt"):
            raise PipelineValidationError(f"{op['name']}: semantic op needs prompt")
        if not op.get("model"):
            raise PipelineValidationError(f"{op['name']}: semantic op needs model")
        if t in ("map", "parallel_map", "reduce", "filter") and \
                not op.get("output_schema"):
            raise PipelineValidationError(f"{op['name']}: needs output_schema")
    if t == "extract":
        if not op.get("prompt") or not op.get("model"):
            raise PipelineValidationError(f"{op['name']}: extract needs prompt+model")
    if t in CODE_TYPES and not op.get("code"):
        raise PipelineValidationError(f"{op['name']}: code op needs CodeSpec")
    if t == "reduce" and "reduce_key" not in op:
        raise PipelineValidationError(f"{op['name']}: reduce needs reduce_key "
                                      "(may be '_all')")
    if t == "split" and not op.get("chunk_size"):
        raise PipelineValidationError(f"{op['name']}: split needs chunk_size")
    if t == "sample":
        if op.get("method") not in ("random", "bm25", "embedding", "stratified"):
            raise PipelineValidationError(f"{op['name']}: bad sample method")
        if not op.get("size"):
            raise PipelineValidationError(f"{op['name']}: sample needs size")


def validate_pipeline(pipeline: PipelineConfig) -> None:
    """Structural validation + schema closure: every field a downstream op
    references must be produced upstream or exist in the source dataset
    (we can't know source fields statically, so we check fields produced
    by earlier ops are not consumed before they exist)."""
    ops = pipeline.get("operators", [])
    if not ops:
        raise PipelineValidationError("pipeline has no operators")
    names = set()
    for op in ops:
        validate_operator(op)
        if op["name"] in names:
            raise PipelineValidationError(f"duplicate op name {op['name']}")
        names.add(op["name"])
    produced: set = set()
    for op in ops:
        for field in op.get("requires", []):
            # 'requires' marks fields produced by a previous operator
            if field not in produced:
                raise PipelineValidationError(
                    f"{op['name']} requires field {field!r} before it is "
                    "produced")
        produced |= set((op.get("output_schema") or {}).keys())


def output_fields(pipeline: PipelineConfig) -> set:
    out: set = set()
    for op in pipeline["operators"]:
        out |= set((op.get("output_schema") or {}).keys())
    return out


def count_llm_ops(pipeline: PipelineConfig) -> int:
    return sum(1 for op in pipeline["operators"] if op["type"] in LLM_TYPES)


def describe(pipeline: PipelineConfig) -> str:
    parts = []
    for op in pipeline["operators"]:
        model = op.get("model", "")
        parts.append(f"{op['type']}({op['name']}{',' + model if model else ''})")
    return " -> ".join(parts)
